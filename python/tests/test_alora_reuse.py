"""The paper's core numeric claim: cross-model KV-cache reuse is exact.

Pre-activation K/V produced by an aLoRA are bit-identical to the base
model's (§2.3), so blocks prefilled by *any* of {base, aLoRA_i} can be
reused by *any other* of them. These tests script the paper's pipelines
(Fig 4) at the numerics level; the rust integration tests replay the same
scenarios through the serving engine against goldens from this model.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import TINY

jax.config.update("jax_platform_name", "cpu")

CFG = TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG)


def _prompt(n, seed=7):
    return list(
        np.random.default_rng(seed).integers(0, CFG.vocab_size - 64, n)
    )


def test_base_to_alora_reuse_exact(params):
    """base-adapter pipeline: aLoRA eval reusing base-prefilled KV must equal
    a full recompute (Figure 3 / Figure 4 left)."""
    k0, v0 = model.empty_kv(CFG)
    p = 40
    prompt = _prompt(p)
    _, kb, vb = model.run_step(params, CFG, prompt, k0, v0, 0, p,
                               CFG.max_seq_len, None)
    for adapter_id in range(CFG.n_adapters):
        ev = prompt + CFG.invocation_tokens(adapter_id)
        full = model.run_step(params, CFG, ev, k0, v0, 0, len(ev), p,
                              adapter_id)
        reuse = model.run_step(params, CFG, ev, kb, vb, p, len(ev), p,
                               adapter_id)
        np.testing.assert_array_equal(np.asarray(full[0]),
                                      np.asarray(reuse[0]))


def test_alora_to_base_reuse_exact(params):
    """adapter-base pipeline (Appendix C): base reusing an aLoRA's
    pre-activation blocks."""
    k0, v0 = model.empty_kv(CFG)
    p = 36
    prompt = _prompt(p, seed=3)
    adapter_id = 0
    ev = prompt + CFG.invocation_tokens(adapter_id)
    # aLoRA prefill: pre-activation KV (positions < p) is base-identical.
    _, ka, va = model.run_step(params, CFG, ev, k0, v0, 0, len(ev), p,
                               adapter_id)
    # Base extends from position p, reusing the aLoRA's blocks.
    cont = prompt + [5, 6]
    reuse = model.run_step(params, CFG, cont, ka, va, p, len(cont),
                           CFG.max_seq_len, None)
    full = model.run_step(params, CFG, cont, k0, v0, 0, len(cont),
                          CFG.max_seq_len, None)
    np.testing.assert_array_equal(np.asarray(full[0]), np.asarray(reuse[0]))


def test_alora_to_alora_reuse_exact(params):
    """Pre-activation blocks interchange between *different* aLoRAs."""
    k0, v0 = model.empty_kv(CFG)
    p = 32
    prompt = _prompt(p, seed=11)
    ev0 = prompt + CFG.invocation_tokens(0)
    _, ka, va = model.run_step(params, CFG, ev0, k0, v0, 0, len(ev0), p, 0)
    ev1 = prompt + CFG.invocation_tokens(1)
    full = model.run_step(params, CFG, ev1, k0, v0, 0, len(ev1), p, 1)
    reuse = model.run_step(params, CFG, ev1, ka, va, p, len(ev1), p, 1)
    np.testing.assert_array_equal(np.asarray(full[0]), np.asarray(reuse[0]))


def test_lora_reuse_would_be_wrong(params):
    """Negative control: naively reusing base KV under a standard LoRA
    (mask=0 everywhere) gives DIFFERENT logits than the correct full
    recompute — demonstrating why vanilla vLLM must isolate adapter caches
    (the adapter-ID hash salt) and re-prefill on every switch."""
    k0, v0 = model.empty_kv(CFG)
    p = 40
    prompt = _prompt(p, seed=13)
    _, kb, vb = model.run_step(params, CFG, prompt, k0, v0, 0, p,
                               CFG.max_seq_len, None)
    ev = prompt + CFG.invocation_tokens(1)
    correct = model.run_step(params, CFG, ev, k0, v0, 0, len(ev), 0, 1)
    wrong = model.run_step(params, CFG, ev, kb, vb, p, len(ev), 0, 1)
    assert np.abs(np.asarray(correct[0]) - np.asarray(wrong[0])).max() > 1e-3


def test_post_activation_kv_not_base_reusable(params):
    """aLoRA K/V *after* activation differ from base — resumption by the
    base model must re-prefill from the activation point (§2.3)."""
    k0, v0 = model.empty_kv(CFG)
    p = 30
    prompt = _prompt(p, seed=17)
    ev = prompt + CFG.invocation_tokens(2)
    n = len(ev)
    _, ka, _ = model.run_step(params, CFG, ev, k0, v0, 0, n, p, 2)
    _, kb, _ = model.run_step(params, CFG, ev, k0, v0, 0, n,
                              CFG.max_seq_len, None)
    ka, kb = np.asarray(ka), np.asarray(kb)
    np.testing.assert_array_equal(ka[:, :p], kb[:, :p])       # pre: identical
    assert np.abs(ka[:, p:n] - kb[:, p:n]).max() > 1e-3        # post: differ


def test_multi_turn_chain_reuse(params):
    """base → aLoRA → base chain (Fig 4 right): every hop reuses the shared
    prefix; final logits equal the no-reuse recompute."""
    k0, v0 = model.empty_kv(CFG)
    p = 24
    prompt = _prompt(p, seed=19)
    # turn 1: base generates 4 tokens. KV for a sampled token is computed by
    # the step that consumes it, so `computed` (KV coverage) lags len(toks)
    # by one after the loop — exactly how the rust engine tracks it.
    toks = list(prompt)
    k, v = k0, v0
    start = 0
    for _ in range(4):
        logits, k, v = model.run_step(params, CFG, toks, k, v, start,
                                      len(toks), CFG.max_seq_len, None)
        toks.append(int(jnp.argmax(logits)))
        start = len(toks) - 1
    base_len = len(toks)
    computed = base_len - 1  # last sampled token has no KV yet
    # turn 2: aLoRA 1 evaluates, reusing all computed KV
    ev = toks + CFG.invocation_tokens(1)
    ev_reuse = model.run_step(params, CFG, ev, k, v, computed, len(ev),
                              base_len, 1)
    ev_full = model.run_step(params, CFG, ev, k0, v0, 0, len(ev),
                             base_len, 1)
    np.testing.assert_array_equal(np.asarray(ev_reuse[0]),
                                  np.asarray(ev_full[0]))
    # turn 3: base continues from the ORIGINAL k/v (pre-activation blocks),
    # ignoring the adapter's post-activation blocks.
    cont = toks + [9]
    cont_reuse = model.run_step(params, CFG, cont, k, v, computed, len(cont),
                                CFG.max_seq_len, None)
    cont_full = model.run_step(params, CFG, cont, k0, v0, 0, len(cont),
                               CFG.max_seq_len, None)
    np.testing.assert_array_equal(np.asarray(cont_reuse[0]),
                                  np.asarray(cont_full[0]))


def test_block_granular_reuse(params):
    """Reuse at block granularity (vLLM caches only *full* blocks): starting
    recompute from any block boundary <= cached length is exact."""
    k0, v0 = model.empty_kv(CFG)
    p = 40  # 2.5 blocks of 16
    prompt = _prompt(p, seed=23)
    _, kb, vb = model.run_step(params, CFG, prompt, k0, v0, 0, p,
                               CFG.max_seq_len, None)
    ev = prompt + CFG.invocation_tokens(0)
    full = model.run_step(params, CFG, ev, k0, v0, 0, len(ev), p, 0)
    # only 2 full blocks (32 tokens) are cache hits; recompute from 32
    reuse = model.run_step(params, CFG, ev, kb, vb, 32, len(ev), p, 0)
    np.testing.assert_array_equal(np.asarray(full[0]), np.asarray(reuse[0]))
