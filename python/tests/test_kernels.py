"""L1 kernel correctness: Pallas vs pure-jnp oracle (kernels/ref.py).

Hypothesis sweeps shapes/dtypes per the repo testing policy; every case
asserts allclose against the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.alora_qkv import alora_qkv
from compile.kernels.attention import attention, attention_flash

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    # Inputs are unscaled normals, so accumulations reach O(1e2); tolerances
    # are relative to that magnitude (f32 matmul reassociation ~1e-6 rel).
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(
        atol=1e-3, rtol=1e-4)


# ---------------------------------------------------------------------------
# alora_qkv
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    tiles_s=st.integers(1, 4),
    tile_tokens=st.sampled_from([8, 16, 32]),
    d_in=st.sampled_from([32, 64, 128]),
    tiles_o=st.integers(1, 3),
    tile_out=st.sampled_from([32, 64, 128]),
    r=st.sampled_from([8, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    inv_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_alora_qkv_matches_ref(tiles_s, tile_tokens, d_in, tiles_o, tile_out,
                               r, dtype, inv_frac, seed):
    s = tiles_s * tile_tokens
    d_out = tiles_o * tile_out
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(ks[0], (s, d_in), dtype)
    w = _rand(ks[1], (d_in, d_out), dtype)
    a = _rand(ks[2], (d_in, r), dtype)
    b = _rand(ks[3], (r, d_out), dtype)
    inv_start = int(inv_frac * s)
    gate = (jnp.arange(s) >= inv_start).astype(jnp.float32)[:, None]

    got = alora_qkv(x, w, a, b, gate, tile_tokens=tile_tokens,
                    tile_out=tile_out)
    want = ref.alora_qkv_ref(x, w, a, b, gate)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_alora_qkv_gate_zero_is_base():
    """gate=0 must be *exactly* the base projection — the property that
    makes pre-activation KV bit-identical to the base model's."""
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = _rand(ks[0], (32, 64), jnp.float32)
    w = _rand(ks[1], (64, 64), jnp.float32)
    a = _rand(ks[2], (64, 32), jnp.float32)
    b = _rand(ks[3], (32, 64), jnp.float32)
    gate = jnp.zeros((32, 1), jnp.float32)
    got = alora_qkv(x, w, a, b, gate, tile_tokens=16, tile_out=64)
    base = jnp.dot(x, w, preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), atol=1e-6)


def test_alora_qkv_gate_one_is_lora():
    """gate=1 everywhere reproduces a standard LoRA projection."""
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = _rand(ks[0], (32, 64), jnp.float32)
    w = _rand(ks[1], (64, 64), jnp.float32)
    a = _rand(ks[2], (64, 8), jnp.float32)
    b = _rand(ks[3], (8, 64), jnp.float32)
    gate = jnp.ones((32, 1), jnp.float32)
    got = alora_qkv(x, w, a, b, gate, tile_tokens=16, tile_out=64)
    want = x @ w + (x @ a) @ b
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_alora_qkv_mixed_gate_rowwise():
    """Rows are gated independently (heterogeneous invocation points in one
    batch, paper Appendix B)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = _rand(ks[0], (16, 32), jnp.float32)
    w = _rand(ks[1], (32, 32), jnp.float32)
    a = _rand(ks[2], (32, 8), jnp.float32)
    b = _rand(ks[3], (8, 32), jnp.float32)
    gate = (jnp.arange(16) % 2).astype(jnp.float32)[:, None]
    got = np.asarray(alora_qkv(x, w, a, b, gate, tile_tokens=8, tile_out=32))
    base = np.asarray(x @ w)
    lora = np.asarray(x @ w + (x @ a) @ b)
    for t in range(16):
        want = lora[t] if t % 2 else base[t]
        np.testing.assert_allclose(got[t], want, atol=1e-4)


def test_alora_qkv_rejects_bad_tiling():
    x = jnp.zeros((30, 32))
    w = jnp.zeros((32, 32))
    a = jnp.zeros((32, 8))
    b = jnp.zeros((8, 32))
    gate = jnp.zeros((30, 1))
    with pytest.raises(AssertionError):
        alora_qkv(x, w, a, b, gate, tile_tokens=16, tile_out=32)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _bias(s, length):
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    return jnp.where((cols <= rows) & (cols < length), 0.0, -1e30).astype(
        jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(1, 4),
    tiles_q=st.integers(1, 4),
    tile_q=st.sampled_from([8, 16, 32]),
    dh=st.sampled_from([16, 32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    len_frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(h, tiles_q, tile_q, dh, dtype, len_frac, seed):
    s = tiles_q * tile_q
    length = max(1, int(len_frac * s))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(ks[0], (h, s, dh), dtype)
    k = _rand(ks[1], (h, s, dh), dtype)
    v = _rand(ks[2], (h, s, dh), dtype)
    bias = _bias(s, length)
    scale = dh ** -0.5
    got = attention(q, k, v, bias, scale=scale, tile_q=tile_q)
    want = ref.attention_ref(q, k, v, bias, scale)
    np.testing.assert_allclose(np.asarray(got, np.float32)[:, :length],
                               np.asarray(want, np.float32)[:, :length],
                               **_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(
    tile_q=st.sampled_from([16, 32]),
    tile_k=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_flash_matches_ref(tile_q, tile_k, seed):
    h, s, dh = 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(ks[0], (h, s, dh), jnp.float32)
    k = _rand(ks[1], (h, s, dh), jnp.float32)
    v = _rand(ks[2], (h, s, dh), jnp.float32)
    bias = _bias(s, s)
    scale = dh ** -0.5
    got = attention_flash(q, k, v, bias, scale=scale, tile_q=tile_q,
                          tile_k=tile_k)
    want = ref.attention_ref(q, k, v, bias, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_attention_causality():
    """Changing K/V at position j must not affect outputs at i < j."""
    h, s, dh = 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = _rand(ks[0], (h, s, dh), jnp.float32)
    k = _rand(ks[1], (h, s, dh), jnp.float32)
    v = _rand(ks[2], (h, s, dh), jnp.float32)
    bias = _bias(s, s)
    out1 = np.asarray(attention(q, k, v, bias, scale=0.25, tile_q=16))
    k2 = k.at[:, 20].add(100.0)
    v2 = v.at[:, 20].add(100.0)
    out2 = np.asarray(attention(q, k2, v2, bias, scale=0.25, tile_q=16))
    np.testing.assert_allclose(out1[:, :20], out2[:, :20], atol=1e-6)
    assert np.abs(out1[:, 20:] - out2[:, 20:]).max() > 1e-3


def test_attention_padding_ignored():
    """Positions >= length must not influence valid outputs."""
    h, s, dh, length = 2, 32, 16, 17
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = _rand(ks[0], (h, s, dh), jnp.float32)
    k = _rand(ks[1], (h, s, dh), jnp.float32)
    v = _rand(ks[2], (h, s, dh), jnp.float32)
    bias = _bias(s, length)
    out1 = np.asarray(attention(q, k, v, bias, scale=0.25, tile_q=16))
    k2 = k.at[:, length:].set(99.0)
    v2 = v.at[:, length:].set(-99.0)
    out2 = np.asarray(attention(q, k2, v2, bias, scale=0.25, tile_q=16))
    np.testing.assert_allclose(out1[:, :length], out2[:, :length], atol=1e-6)
