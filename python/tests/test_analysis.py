"""Tests for the HLO audit + L1 estimate tooling (compile/analysis.py)."""

import jax

from compile import analysis
from compile.configs import TINY

jax.config.update("jax_platform_name", "cpu")


def test_hlo_histogram_counts_ops():
    text = """
HloModule m
ENTRY e {
  a = f32[2,2]{1,0} parameter(0)
  b = f32[2,2]{1,0} parameter(1)
  d = f32[2,2]{1,0} dot(a, b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT r = f32[2,2]{1,0} add(d, a)
}
"""
    ops = analysis.hlo_op_histogram(text)
    assert ops.get("dot") == 1
    assert ops.get("add") == 1
    assert ops.get("parameter") == 2


def test_audit_runs_on_real_module():
    report, ops = analysis.audit_step_module()
    assert report["total_ops"] > 100
    assert report["dot"] > 0, "matmuls must be present"
    assert "while" in ops or report["while"] >= 0


def test_qkv_estimate_vmem_under_budget():
    cfg = TINY
    est = analysis.qkv_kernel_estimate(
        cfg.max_seq_len, cfg.d_model, cfg.d_model, cfg.rank,
        cfg.tile_tokens, cfg.tile_out)
    assert est["vmem_frac"] < 0.05, "tiny tiles must be far under VMEM"
    assert 0 < est["mxu_util_base"] <= 1.0
    assert est["flops"] > 0


def test_qkv_estimate_scales_with_tiles():
    small = analysis.qkv_kernel_estimate(160, 128, 128, 32, 8, 32)
    big = analysis.qkv_kernel_estimate(160, 128, 128, 32, 32, 128)
    assert big["vmem_bytes_per_cell"] > small["vmem_bytes_per_cell"]
    assert big["grid_cells"] < small["grid_cells"]
    assert big["mxu_util_base"] > small["mxu_util_base"]


def test_attention_estimate_sane():
    cfg = TINY
    est = analysis.attention_kernel_estimate(
        cfg.max_seq_len, cfg.n_heads, cfg.head_dim, cfg.tile_tokens)
    assert est["grid_cells"] == cfg.n_heads * cfg.max_seq_len // cfg.tile_tokens
    assert est["vmem_frac"] < 0.05


def test_tile_sweep_includes_current_config():
    rows = analysis.sweep_qkv_tiles(TINY)
    assert any((tt, to) == (TINY.tile_tokens, TINY.tile_out) for tt, to, _ in rows)
    assert len(rows) >= 6
