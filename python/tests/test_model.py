"""L2 model correctness: Pallas step vs pure-jnp step_ref, contract checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import TINY

jax.config.update("jax_platform_name", "cpu")

CFG = TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG)


def _prompt(n, seed=0):
    return list(
        np.random.default_rng(seed).integers(0, CFG.vocab_size - 32, n)
    )


def test_pallas_step_matches_ref(params):
    k0, v0 = model.empty_kv(CFG)
    prompt = _prompt(48)
    ref_logits, ref_k, ref_v = model.run_step(
        params, CFG, prompt, k0, v0, 0, 48, CFG.max_seq_len, None)
    pal_logits, pal_k, pal_v = model.run_step(
        params, CFG, prompt, k0, v0, 0, 48, CFG.max_seq_len, None,
        use_pallas=True)
    np.testing.assert_allclose(np.asarray(pal_logits), np.asarray(ref_logits),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(pal_k), np.asarray(ref_k),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(pal_v), np.asarray(ref_v),
                               atol=1e-4, rtol=1e-4)


def test_pallas_step_matches_ref_with_adapter(params):
    k0, v0 = model.empty_kv(CFG)
    tokens = _prompt(40) + CFG.invocation_tokens(2)
    n = len(tokens)
    for adapter_id in range(CFG.n_adapters):
        ref_out = model.run_step(params, CFG, tokens, k0, v0, 0, n, 40,
                                 adapter_id)
        pal_out = model.run_step(params, CFG, tokens, k0, v0, 0, n, 40,
                                 adapter_id, use_pallas=True)
        np.testing.assert_allclose(np.asarray(pal_out[0]),
                                   np.asarray(ref_out[0]),
                                   atol=1e-4, rtol=1e-4)


def test_kv_passthrough_outside_window(params):
    """K/V outside [start, length) must be returned untouched — the property
    that lets the rust block manager own cache lifetime."""
    kin = jnp.full(model.kv_shape(CFG), 7.5, jnp.float32)
    vin = jnp.full(model.kv_shape(CFG), -3.25, jnp.float32)
    prompt = _prompt(30)
    _, k, v = model.run_step(params, CFG, prompt + [1] * 10, kin, vin,
                             30, 40, CFG.max_seq_len, None)
    k, v = np.asarray(k), np.asarray(v)
    # positions < start and >= length untouched
    np.testing.assert_array_equal(k[:, :30], 7.5)
    np.testing.assert_array_equal(k[:, 40:], 7.5)
    np.testing.assert_array_equal(v[:, :30], -3.25)
    np.testing.assert_array_equal(v[:, 40:], -3.25)
    # updated window actually written
    assert np.abs(k[:, 30:40] - 7.5).min() > 0


def test_all_pre_mask_equals_base(params):
    """An aLoRA with the mask all-pre must be bit-equivalent to the base
    model regardless of the one-hot — pre-activation tokens never see
    adapter weights."""
    k0, v0 = model.empty_kv(CFG)
    prompt = _prompt(32)
    base = model.run_step(params, CFG, prompt, k0, v0, 0, 32,
                          CFG.max_seq_len, None)
    for adapter_id in range(CFG.n_adapters):
        ad = model.run_step(params, CFG, prompt, k0, v0, 0, 32,
                            CFG.max_seq_len, adapter_id)
        np.testing.assert_array_equal(np.asarray(base[0]), np.asarray(ad[0]))
        np.testing.assert_array_equal(np.asarray(base[1]), np.asarray(ad[1]))


def test_lora_mask_changes_kv(params):
    """mask=0 everywhere (standard LoRA) must produce *different* K/V for
    the prompt — why LoRA cannot reuse base cache."""
    k0, v0 = model.empty_kv(CFG)
    prompt = _prompt(32)
    _, kb, _ = model.run_step(params, CFG, prompt, k0, v0, 0, 32,
                              CFG.max_seq_len, None)
    _, kl, _ = model.run_step(params, CFG, prompt, k0, v0, 0, 32, 0, 1)
    assert np.abs(np.asarray(kb)[:, :32] - np.asarray(kl)[:, :32]).max() > 1e-3


def test_decode_equals_prefill_suffix(params):
    """Token-by-token decode over cached KV must equal a one-shot prefill."""
    k0, v0 = model.empty_kv(CFG)
    toks = _prompt(20)
    # one-shot
    one_logits, k1, v1 = model.run_step(params, CFG, toks, k0, v0, 0, 20,
                                        CFG.max_seq_len, None)
    # incremental: prefill 16, then 4 single-token extensions
    _, k, v = model.run_step(params, CFG, toks, k0, v0, 0, 16,
                             CFG.max_seq_len, None)
    logits = None
    for i in range(16, 20):
        logits, k, v = model.run_step(params, CFG, toks, k, v, i, i + 1,
                                      CFG.max_seq_len, None)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(one_logits),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(k)[:, :20], np.asarray(k1)[:, :20],
                               atol=1e-4, rtol=1e-4)


def test_logits_at_length_minus_one(params):
    """Shortening length must move the readout position."""
    k0, v0 = model.empty_kv(CFG)
    toks = _prompt(24)
    l24 = model.run_step(params, CFG, toks, k0, v0, 0, 24,
                         CFG.max_seq_len, None)[0]
    l12 = model.run_step(params, CFG, toks, k0, v0, 0, 12,
                         CFG.max_seq_len, None)[0]
    l12b = model.run_step(params, CFG, toks[:12], k0, v0, 0, 12,
                          CFG.max_seq_len, None)[0]
    assert np.abs(np.asarray(l24) - np.asarray(l12)).max() > 1e-3
    np.testing.assert_allclose(np.asarray(l12), np.asarray(l12b), atol=1e-5)


def test_param_count_matches_config():
    p = model.init_params(CFG)
    total = sum(np.asarray(x).size for x in jax.tree.leaves(p))
    assert total == CFG.param_count()


def test_invocation_tokens_disjoint_and_in_vocab():
    seen = set()
    for a in range(CFG.n_adapters):
        toks = CFG.invocation_tokens(a)
        assert len(toks) == CFG.invocation_len
        assert all(0 <= t < CFG.vocab_size for t in toks)
        assert not (set(toks) & seen), "invocation sequences must be disjoint"
        seen |= set(toks)
