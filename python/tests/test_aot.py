"""AOT path: lowering determinism, manifest consistency, golden validity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.configs import TINY

jax.config.update("jax_platform_name", "cpu")

CFG = TINY
ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG)


def test_lowering_produces_parseable_hlo_text(params):
    text = aot.to_hlo_text(aot.lower_step(params, CFG))
    assert text.startswith("HloModule")
    assert "{...}" not in text, "large constants must not be elided"
    # entry signature carries exactly the 7 runtime args
    assert "s32[160]" in text and "f32[4,160,4,32]" in text


def test_lowering_is_deterministic(params):
    t1 = aot.to_hlo_text(aot.lower_step(params, CFG))
    t2 = aot.to_hlo_text(aot.lower_step(params, CFG))
    assert t1 == t2


def test_manifest_matches_config():
    m = aot.manifest(CFG)
    assert m["max_seq_len"] == CFG.max_seq_len
    assert m["block_size"] == CFG.block_size
    assert [a["name"] for a in m["args"]] == [
        "tokens", "k_in", "v_in", "start", "length", "mask_pre",
        "adapter_onehot",
    ]
    assert m["invocation_tokens"] == [
        CFG.invocation_tokens(a) for a in range(CFG.n_adapters)
    ]


def test_golden_scenario_selfconsistent(params):
    """Rebuild the golden dict and re-verify its claims with fresh runs."""
    g = aot.build_golden(params, CFG)
    np.testing.assert_allclose(g["alora_full_logits_head"],
                               g["alora_reuse_logits_head"], atol=1e-6)
    # LoRA head must differ from aLoRA head somewhere
    d = np.abs(np.array(g["lora_logits_head"]) -
               np.array(g["alora_full_logits_head"]))
    assert d.max() > 1e-3
    # replay base prefill and check the exported head
    k0, v0 = model.empty_kv(CFG)
    logits, _, _ = model.run_step(params, CFG, g["prompt"], k0, v0, 0,
                                  g["prompt_len"], CFG.max_seq_len, None)
    np.testing.assert_allclose(np.asarray(logits)[:g["logits_head_n"]],
                               g["base_logits_head"], atol=1e-5)
    assert int(jnp.argmax(logits)) == g["base_next_token"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "golden.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_emitted_golden_matches_current_model(params):
    """The checked-out artifacts must correspond to the current model code —
    guards against stale artifacts after model changes."""
    with open(os.path.join(ART, "golden.json")) as f:
        g = json.load(f)
    k0, v0 = model.empty_kv(CFG)
    logits, _, _ = model.run_step(params, CFG, g["prompt"], k0, v0, 0,
                                  g["prompt_len"], CFG.max_seq_len, None)
    np.testing.assert_allclose(np.asarray(logits)[:g["logits_head_n"]],
                               g["base_logits_head"], atol=1e-5)


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_emitted_manifest_matches_current_config():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    assert m == aot.manifest(CFG)
