"""Model configurations for the AOT compile path.

Only the `tiny` config is actually lowered to an executable artifact — it is
the model that runs on the PJRT CPU client from the rust coordinator. The
large configs from the paper's Table 1 (Granite 3.2 8B, Llama 3.3 70B,
Mistral Large 2) exist on the rust side as *cost-model presets* for the
discrete-event simulator (see rust/src/config/presets.rs and DESIGN.md §7).

All shapes here are static: the rust runtime executes one fixed-shape
`step` artifact, so max_seq_len bounds the KV buffer and prompt+generation
lengths of the real-model path.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class TinyConfig:
    """~0.9M-parameter transformer used on the real PJRT path.

    The paper's speedups are independent of weight values ("all low-rank
    adapters and all inputs were generated randomly, as the values of these
    do not affect inference speed" — §4.1), so a tiny deterministic model is
    sufficient to validate the *numerics* of cross-model KV-cache reuse;
    large-model timing behaviour is reproduced by the rust simulator.
    """

    vocab_size: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 256
    max_seq_len: int = 160
    # KV-cache block size used by the rust block manager. Must divide
    # max_seq_len. Matches the Figure-3 example semantics (activation
    # tokens only cached once they fill a block).
    block_size: int = 16
    # Number of baked-in adapters selectable via one-hot at runtime.
    n_adapters: int = 3
    # Rank of the baked aLoRA adapters (paper §4.1 uses 32 for aLoRA).
    rank: int = 32
    # Length of each adapter's invocation (activation) token sequence.
    invocation_len: int = 4
    rms_eps: float = 1e-5
    seed: int = 0

    # Pallas tiling knobs (see DESIGN.md §8 / §11 for the VMEM story).
    # Perf pass (EXPERIMENTS.md §Perf): at tiny-model shapes, whole-sequence
    # token tiles maximize the MXU-utilization estimate (0.25 -> 1.0) at
    # 1.6% of VMEM and run the compiled artifact 2.1x faster than tile 16;
    # on production shapes the same sweep would cap tiles at the VMEM
    # budget instead. Sweep: `python -m compile.aot --tile-tokens N`.
    tile_tokens: int = 160     # token-axis tile for qkv projection + attention
    tile_out: int = 128        # output-feature tile for qkv projection

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_blocks(self) -> int:
        assert self.max_seq_len % self.block_size == 0
        return self.max_seq_len // self.block_size

    def param_count(self) -> int:
        d, L = self.d_model, self.n_layers
        embed = self.vocab_size * d + self.max_seq_len * d
        attn = 4 * d * d            # Wq Wk Wv Wo
        mlp = 2 * d * self.d_ff
        norms = 2 * d
        adapters = self.n_adapters * L * 3 * (d * self.rank + self.rank * d)
        return embed + L * (attn + mlp + norms) + d + adapters

    def invocation_tokens(self, adapter_id: int) -> list[int]:
        """Deterministic invocation sequence for adapter `adapter_id`.

        Mirrored byte-for-byte by rust/src/adapter/registry.rs — the rust
        coordinator scans prompts for these sequences to locate the aLoRA
        activation point (paper Figure 5).
        """
        assert 0 <= adapter_id < self.n_adapters
        base = self.vocab_size - (adapter_id + 1) * self.invocation_len
        return list(range(base, base + self.invocation_len))


TINY = TinyConfig()
