"""Pallas kernel: blocked masked attention over the paged KV buffer (L1).

The rust coordinator manages KV in PagedAttention-style blocks; by the time
the executable runs, the (gathered) KV buffer is a dense padded [H, S, Dh]
tensor whose valid region is encoded in an additive bias. The kernel tiles
the query axis per head — each grid cell holds one q tile plus that head's
full K/V in VMEM:

    VMEM per cell (f32, defaults Tq=32, S=160, Dh=32):
        q 32×32 + K,V 2×160×32 + bias 32×160 + out 32×32  ≈ 69 KiB

For the tiny model a whole head's KV fits VMEM, so a single-pass softmax
per q tile is optimal (no K-axis loop, no rescaling traffic). On real
hardware with long S the K axis would be tiled with a running-max
(flash-style) inner loop; that variant exists as `attention_flash` below
and is exercised by tests and the L1 block-shape sweep.

interpret=True for CPU-PJRT executability (see alora_qkv.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(scale, q_ref, k_ref, v_ref, bias_ref, o_ref):
    """Grid cell: (head h, q-tile i). Full K/V for head h in VMEM."""
    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) + bias_ref[...]
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.dot(p, v, preferred_element_type=jnp.float32)
    o_ref[0] = (o / jnp.sum(p, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "tile_q"))
def attention(q, k, v, bias, *, scale, tile_q=32):
    """Masked attention, blocked over (head, q-tile).

    Args:
        q, k, v: [H, S, Dh]; S divisible by tile_q.
        bias:    [S, S] additive mask (0 allowed / -1e30 disallowed),
                 encoding causality and the valid KV length.
        scale:   softmax scale (1/sqrt(Dh)).
        tile_q:  query-axis tile.

    Returns:
        [H, S, Dh] in q's dtype.
    """
    h, s, dh = q.shape
    assert s % tile_q == 0, (s, tile_q)
    grid = (h, s // tile_q)
    kernel = functools.partial(_attn_kernel, float(scale))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_q, dh), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((1, s, dh), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((tile_q, s), lambda hh, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, dh), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, dh), q.dtype),
        interpret=True,
    )(q, k, v, bias)


def _flash_kernel(scale, n_kv, q_ref, k_ref, v_ref, bias_ref, o_ref):
    """Flash-style grid cell: K axis tiled with running-max rescaling.

    k_ref/v_ref/bias_ref hold the full row for this head / q-tile; the loop
    slices K tiles out of VMEM. On real TPU the BlockSpec would stream K
    tiles HBM→VMEM instead; the loop structure (running max `m`, running
    normalizer `l`, rescaled accumulator) is the part that transfers.
    """
    q = q_ref[0].astype(jnp.float32) * scale
    tq, dh = q.shape
    s_total = k_ref.shape[1]
    tk = s_total // n_kv

    def body(j, carry):
        acc, m, l = carry
        kj = jax.lax.dynamic_slice(k_ref[0], (j * tk, 0), (tk, dh)).astype(jnp.float32)
        vj = jax.lax.dynamic_slice(v_ref[0], (j * tk, 0), (tk, dh)).astype(jnp.float32)
        bj = jax.lax.dynamic_slice(bias_ref[...], (0, j * tk), (tq, tk))
        sj = jnp.dot(q, kj.T, preferred_element_type=jnp.float32) + bj
        mj = jnp.maximum(m, jnp.max(sj, axis=-1, keepdims=True))
        p = jnp.exp(sj - mj)
        alpha = jnp.exp(m - mj)
        acc = acc * alpha + jnp.dot(p, vj, preferred_element_type=jnp.float32)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        return acc, mj, l

    acc0 = jnp.zeros((tq, dh), jnp.float32)
    m0 = jnp.full((tq, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((tq, 1), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "tile_q", "tile_k"))
def attention_flash(q, k, v, bias, *, scale, tile_q=32, tile_k=32):
    """Flash-style variant of `attention` with a tiled K axis.

    Numerically equivalent to `attention` / `attention_ref`; used for the
    L1 structure ablation (EXPERIMENTS.md §Perf) and long-S settings where
    a head's KV would not fit VMEM.
    """
    h, s, dh = q.shape
    assert s % tile_q == 0 and s % tile_k == 0, (s, tile_q, tile_k)
    grid = (h, s // tile_q)
    kernel = functools.partial(_flash_kernel, float(scale), s // tile_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_q, dh), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((1, s, dh), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((tile_q, s), lambda hh, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, dh), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, dh), q.dtype),
        interpret=True,
    )(q, k, v, bias)
