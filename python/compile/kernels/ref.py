"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its `*_ref` twin to float tolerance under pytest (including
hypothesis sweeps over shapes/dtypes in python/tests/test_kernels.py).

The reference implementations are also used directly by `model.step_ref`,
the no-Pallas reference forward pass that the full Pallas model is checked
against end-to-end.
"""

import jax.numpy as jnp


def alora_qkv_ref(x, w, a, b, gate):
    """Activation-aware adapted projection (paper §2.3, Algorithm 1).

        out[t] = x[t] @ W + gate[t] * ((x[t] @ A) @ B)

    `gate[t] = 0` for tokens *before* the aLoRA invocation point (base
    behaviour — identical K/V to the base model, which is exactly what
    makes the KV-cache reusable across models) and `1` after it. A standard
    LoRA is the special case `gate = 1` everywhere; the base model is
    `gate = 0` everywhere (or zero A/B).

    Args:
        x:    [S, d_in]  activations.
        w:    [d_in, d_out] frozen base projection.
        a:    [d_in, r]  low-rank down-projection (already adapter-selected).
        b:    [r, d_out] low-rank up-projection.
        gate: [S, 1]     1.0 where the adapter is active for that token.

    Returns:
        [S, d_out] projected activations, float32 accumulation.
    """
    base = jnp.dot(x, w, preferred_element_type=jnp.float32)
    corr = jnp.dot(
        jnp.dot(x, a, preferred_element_type=jnp.float32),
        b,
        preferred_element_type=jnp.float32,
    )
    return (base + gate * corr).astype(x.dtype)


def attention_ref(q, k, v, bias, scale):
    """Masked multi-head attention over a padded sequence.

    Args:
        q, k, v: [H, S, Dh].
        bias:    [S, S] additive mask; 0 where position i may attend to j,
                 large-negative otherwise (encodes causality + the valid
                 length of the padded KV buffer).
        scale:   softmax scale, typically 1/sqrt(Dh).

    Returns:
        [H, S, Dh] attention outputs.
    """
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("hqd,hkd->hqk", qf, kf) * scale + bias[None, :, :]
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("hqk,hkd->hqd", p, vf) / jnp.sum(p, axis=-1, keepdims=True)
    return out.astype(q.dtype)
