"""Pallas kernel: fused activation-aware adapted projection (L1 hot spot).

This is the paper's Algorithm-1 computation — base projection plus a
per-token-gated low-rank correction — fused into a single tiled kernel:

    out = x @ W + gate ⊙ ((x @ A) @ B)

GPU→TPU adaptation (DESIGN.md §8): the paper implements this as a masked
add around vLLM's CUDA LoRA path. On TPU we instead tile the token axis so
that each (token-tile, out-tile) grid cell issues one MXU matmul for the
base projection and two *skinny* (rank-32) matmuls for the correction, all
resident in VMEM. The gate is applied per token-row in the tile, so a batch
mixing pre- and post-activation tokens (heterogeneous invocation points,
paper Appendix B) is handled inside one kernel launch — no per-request
dispatch.

VMEM footprint per grid cell (f32):
    x tile   Ts×d_in, W tile d_in×To, A d_in×r, B tile r×To,
    gate Ts×1, out Ts×To
With the defaults (Ts=32, To=128, d_in=128, r=32) that is ~37 KiB — far
under the ~16 MiB/core VMEM budget, leaving room to scale Ts/To up on real
hardware (see EXPERIMENTS.md §Perf for the block-shape sweep).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO through the pallas
interpreter. Structure (tiling, fusion, accumulation dtype) is what we
optimize; wallclock on CPU is not a TPU proxy.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _alora_qkv_kernel(x_ref, w_ref, a_ref, b_ref, gate_ref, o_ref):
    """One (token-tile, out-tile) grid cell.

    Shapes (per tile):
        x_ref:    (Ts, d_in)
        w_ref:    (d_in, To)
        a_ref:    (d_in, r)
        b_ref:    (r, To)
        gate_ref: (Ts, 1)
        o_ref:    (Ts, To)
    """
    x = x_ref[...]
    # Base path: one MXU-shaped matmul, f32 accumulation.
    base = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    # Adapter path: two skinny matmuls through the rank-r bottleneck.
    xa = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    corr = jnp.dot(xa, b_ref[...], preferred_element_type=jnp.float32)
    # Per-token gate: 0 before the invocation point (base behaviour),
    # 1 after it. This single line is the aLoRA masking of Algorithm 1.
    o_ref[...] = (base + gate_ref[...] * corr).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_tokens", "tile_out"))
def alora_qkv(x, w, a, b, gate, *, tile_tokens=32, tile_out=128):
    """Fused adapted projection. See module docstring.

    Args:
        x:    [S, d_in] activations; S divisible by tile_tokens.
        w:    [d_in, d_out] frozen base weight; d_out divisible by tile_out.
        a:    [d_in, r] adapter down-projection (adapter-selected upstream).
        b:    [r, d_out] adapter up-projection.
        gate: [S, 1] float, 1.0 where the adapter is active for that token.
        tile_tokens / tile_out: tile sizes for the (token, feature) grid.

    Returns:
        [S, d_out] with x's dtype; f32 accumulation inside.
    """
    s, d_in = x.shape
    d_in_w, d_out = w.shape
    r = a.shape[1]
    assert d_in == d_in_w, (d_in, d_in_w)
    assert s % tile_tokens == 0, (s, tile_tokens)
    assert d_out % tile_out == 0, (d_out, tile_out)
    assert gate.shape == (s, 1), gate.shape

    grid = (s // tile_tokens, d_out // tile_out)
    return pl.pallas_call(
        _alora_qkv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_tokens, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((d_in, tile_out), lambda i, j: (0, j)),
            pl.BlockSpec((d_in, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, tile_out), lambda i, j: (0, j)),
            pl.BlockSpec((tile_tokens, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_tokens, tile_out), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, d_out), x.dtype),
        interpret=True,
    )(x, w, a, b, gate)
