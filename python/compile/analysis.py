"""L2/L1 performance analysis: HLO op audit + VMEM/MXU estimates.

    cd python && python -m compile.analysis

Two jobs (DESIGN.md §11):

1. **L2 HLO audit** — count ops in the lowered `step` module, flag
   recomputation smells (duplicate large matmuls), and report the
   total FLOPs/bytes so the L3 cost model and the artifact agree.
2. **L1 structure estimates** — per Pallas kernel and tile configuration,
   compute the VMEM working set and the MXU utilization proxy
   (fraction of the matmul's inner dimensions that fill the 128×128
   systolic array). interpret=True gives CPU-numpy timings only, so
   *structure* is what we optimize; these numbers are the ones recorded
   in EXPERIMENTS.md §Perf.
"""

import collections
import re

from . import aot, model
from .configs import TINY


def hlo_op_histogram(hlo_text: str) -> dict:
    """Count HLO opcodes in the entry + nested computations."""
    ops = collections.Counter()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\S+\s+([a-z\-]+)\(", line)
        if m:
            ops[m.group(1)] += 1
    return dict(ops)


def audit_step_module(cfg=TINY):
    params = model.init_params(cfg)
    text = aot.to_hlo_text(aot.lower_step(params, cfg))
    ops = hlo_op_histogram(text)
    dots = ops.get("dot", 0)
    # Expected dot count: per layer 3 QKV pallas kernels × (1 base + 2
    # adapter dots per grid cell, grid cells unrolled or looped) + attn
    # (2 dots per cell) + wo + mlp(2) + lm head.
    report = {
        "total_ops": sum(ops.values()),
        "dot": dots,
        "while": ops.get("while", 0),
        "dynamic-update-slice": ops.get("dynamic-update-slice", 0),
        "transpose": ops.get("transpose", 0),
        "bytes_hlo_text": len(text),
    }
    return report, ops


# ---------------------------------------------------------------------------
# L1 estimates
# ---------------------------------------------------------------------------

MXU_DIM = 128            # TPU systolic array edge
VMEM_BYTES = 16 * 2**20  # ~16 MiB/core


def qkv_kernel_estimate(s, d_in, d_out, r, tile_tokens, tile_out, dtype_bytes=4):
    """VMEM working set + MXU utilization proxy for alora_qkv tiles."""
    vmem = dtype_bytes * (
        tile_tokens * d_in      # x tile
        + d_in * tile_out       # W tile
        + d_in * r              # A
        + r * tile_out          # B tile
        + tile_tokens           # gate
        + tile_tokens * tile_out  # out
    )
    # MXU proxy: each dot's (M, K, N) vs the 128×128 array. The base matmul
    # dominates; utilization ~ min(dim,128)/128 per axis.
    def util(m, k, n):
        return (min(m, MXU_DIM) / MXU_DIM) * (min(k, MXU_DIM) / MXU_DIM) * (
            min(n, MXU_DIM) / MXU_DIM)

    base_util = util(tile_tokens, d_in, tile_out)
    corr_util = 0.5 * (util(tile_tokens, d_in, r) + util(tile_tokens, r, tile_out))
    grid = (s // tile_tokens) * (d_out // tile_out)
    flops = 2 * s * d_in * d_out + 2 * s * (d_in * r + r * d_out)
    return {
        "grid_cells": grid,
        "vmem_bytes_per_cell": vmem,
        "vmem_frac": vmem / VMEM_BYTES,
        "mxu_util_base": base_util,
        "mxu_util_adapter": corr_util,
        "flops": flops,
    }


def attention_kernel_estimate(s, h, dh, tile_q, dtype_bytes=4):
    vmem = dtype_bytes * (
        tile_q * dh         # q tile
        + 2 * s * dh        # K, V for the head
        + tile_q * s        # bias tile
        + tile_q * dh       # out
        + tile_q * s        # scores scratch
    )
    def util(m, k, n):
        return (min(m, MXU_DIM) / MXU_DIM) * (min(k, MXU_DIM) / MXU_DIM) * (
            min(n, MXU_DIM) / MXU_DIM)
    return {
        "grid_cells": h * (s // tile_q),
        "vmem_bytes_per_cell": vmem,
        "vmem_frac": vmem / VMEM_BYTES,
        "mxu_util_scores": util(tile_q, dh, s),
        "mxu_util_values": util(tile_q, s, dh),
        "flops": 4 * h * s * s * dh,
    }


def sweep_qkv_tiles(cfg=TINY):
    """Block-shape sweep for the fused QKV kernel (the L1 §Perf table)."""
    rows = []
    for tt in (8, 16, 32, 80, 160):
        for to in (32, 64, 128):
            if cfg.max_seq_len % tt or cfg.d_model % to:
                continue
            est = qkv_kernel_estimate(
                cfg.max_seq_len, cfg.d_model, cfg.d_model, cfg.rank, tt, to)
            rows.append((tt, to, est))
    return rows


def main():
    report, ops = audit_step_module()
    print("== L2 HLO audit (tiny step module) ==")
    for k, v in report.items():
        print(f"  {k:>24}: {v}")
    top = sorted(ops.items(), key=lambda kv: -kv[1])[:12]
    print("  top ops:", ", ".join(f"{k}×{v}" for k, v in top))

    cfg = TINY
    print("\n== L1 alora_qkv tile sweep (VMEM / MXU-util estimates) ==")
    print(f"  {'tile_t':>6} {'tile_o':>6} {'grid':>5} {'VMEM/cell':>10} "
          f"{'%VMEM':>6} {'MXU(base)':>9}")
    for tt, to, est in sweep_qkv_tiles(cfg):
        star = " <= current" if (tt, to) == (cfg.tile_tokens, cfg.tile_out) else ""
        print(f"  {tt:>6} {to:>6} {est['grid_cells']:>5} "
              f"{est['vmem_bytes_per_cell']:>10,} {est['vmem_frac']*100:>5.1f}% "
              f"{est['mxu_util_base']:>9.3f}{star}")

    print("\n== L1 attention (per-head K/V resident) ==")
    est = attention_kernel_estimate(cfg.max_seq_len, cfg.n_heads, cfg.head_dim,
                                    cfg.tile_tokens)
    for k, v in est.items():
        print(f"  {k:>22}: {v:,}" if isinstance(v, int) else f"  {k:>22}: {v:.4f}")


if __name__ == "__main__":
    main()
