"""AOT compile path: lower the L2 `step` to HLO text + export goldens.

    cd python && python -m compile.aot --out-dir ../artifacts

Produces:
    tiny_step.hlo.txt   — the executable the rust runtime loads (PJRT CPU).
                          Weights are baked in as constants; the only
                          runtime inputs are tokens/KV/window/mask/one-hot.
    manifest.json       — shapes, dtypes and argument order for rust.
    golden.json         — scripted multi-turn scenario with expected logits
                          so rust/tests/real_runtime.rs can verify the
                          cross-model KV-reuse numerics end-to-end.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` rust crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONLY here — never on the request path. `make artifacts` is a
no-op when inputs are unchanged (mtime-based, via the Makefile rule).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import TINY, TinyConfig


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked weights must survive the text
    # round-trip — the default printer elides them as `constant({...})`.
    return comp.as_hlo_text(print_large_constants=True)


def lower_step(params, cfg: TinyConfig):
    """Lower `step` with weights closed over as constants."""

    def fn(tokens, k_in, v_in, start, length, mask_pre, adapter_onehot):
        logits, k, v = model.step(
            params, cfg, tokens, k_in, v_in, start, length, mask_pre,
            adapter_onehot,
        )
        return logits, k, v

    s = cfg.max_seq_len
    kv = jax.ShapeDtypeStruct(model.kv_shape(cfg), jnp.float32)
    specs = (
        jax.ShapeDtypeStruct((s,), jnp.int32),          # tokens
        kv,                                             # k_in
        kv,                                             # v_in
        jax.ShapeDtypeStruct((), jnp.int32),            # start
        jax.ShapeDtypeStruct((), jnp.int32),            # length
        jax.ShapeDtypeStruct((s,), jnp.float32),        # mask_pre
        jax.ShapeDtypeStruct((cfg.n_adapters,), jnp.float32),  # adapter_onehot
    )
    # Perf pass: donate the KV buffers. The input_output_alias survives the
    # HLO-text round-trip (verified in EXPERIMENTS.md §Perf), letting the
    # PJRT runtime update KV in place instead of materializing fresh
    # 327 KiB outputs per step.
    return jax.jit(fn, donate_argnums=(1, 2)).lower(*specs)


def manifest(cfg: TinyConfig) -> dict:
    return {
        "model": "tiny",
        "vocab_size": cfg.vocab_size,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "head_dim": cfg.head_dim,
        "max_seq_len": cfg.max_seq_len,
        "block_size": cfg.block_size,
        "n_adapters": cfg.n_adapters,
        "rank": cfg.rank,
        "invocation_len": cfg.invocation_len,
        "invocation_tokens": [
            cfg.invocation_tokens(a) for a in range(cfg.n_adapters)
        ],
        "args": [
            {"name": "tokens", "shape": [cfg.max_seq_len], "dtype": "s32"},
            {"name": "k_in", "shape": list(model.kv_shape(cfg)), "dtype": "f32"},
            {"name": "v_in", "shape": list(model.kv_shape(cfg)), "dtype": "f32"},
            {"name": "start", "shape": [], "dtype": "s32"},
            {"name": "length", "shape": [], "dtype": "s32"},
            {"name": "mask_pre", "shape": [cfg.max_seq_len], "dtype": "f32"},
            {"name": "adapter_onehot", "shape": [cfg.n_adapters], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "logits", "shape": [cfg.vocab_size], "dtype": "f32"},
            {"name": "k_out", "shape": list(model.kv_shape(cfg)), "dtype": "f32"},
            {"name": "v_out", "shape": list(model.kv_shape(cfg)), "dtype": "f32"},
        ],
    }


def build_golden(params, cfg: TinyConfig) -> dict:
    """Scripted multi-turn base→aLoRA→base scenario with expected logits.

    The scenario mirrors the paper's atomic pipeline (§4.1): base prefill
    over prompt x, adapter evaluation over (x + invocation), and a final
    base continuation — exercising reuse in both directions (Fig 4).

    Uses the *reference* (pure-jnp) path so goldens are independent of the
    Pallas kernels; pytest separately proves pallas == ref, and the rust
    test proves artifact == golden, closing the triangle.
    """
    rng = jax.random.PRNGKey(123)
    prompt_len = 40
    prompt = jax.random.randint(
        rng, (prompt_len,), 0, cfg.vocab_size - 4 * cfg.invocation_len
    ).tolist()
    adapter_id = 1
    inv = cfg.invocation_tokens(adapter_id)

    k0, v0 = model.empty_kv(cfg)

    # (1) Base prefill over the prompt.
    base_logits, k1, v1 = model.run_step(
        params, cfg, prompt, k0, v0, 0, prompt_len,
        inv_start=cfg.max_seq_len, adapter_id=None,
    )
    y = int(jnp.argmax(base_logits))

    # (2a) aLoRA eval, FULL recompute (what a cache-miss would do).
    eval_tokens = prompt + [y] + inv
    inv_start = prompt_len + 1
    full_logits, kf, vf = model.run_step(
        params, cfg, eval_tokens, k0, v0, 0, len(eval_tokens),
        inv_start=inv_start, adapter_id=adapter_id,
    )

    # (2b) aLoRA eval REUSING base-prefilled KV — the paper's contribution.
    # Only [prompt_len, len(eval_tokens)) is recomputed.
    reuse_logits, kr, vr = model.run_step(
        params, cfg, eval_tokens, k1, v1, prompt_len, len(eval_tokens),
        inv_start=inv_start, adapter_id=adapter_id,
    )
    assert jnp.allclose(full_logits, reuse_logits, atol=1e-4), (
        "cross-model KV reuse must be numerically exact"
    )

    # (2c) Standard-LoRA eval (mask 0 everywhere) — differs from base KV,
    # demonstrating why LoRA cannot reuse base cache.
    lora_logits, _, _ = model.run_step(
        params, cfg, eval_tokens, k0, v0, 0, len(eval_tokens),
        inv_start=0, adapter_id=adapter_id,
    )

    # (3) Base continuation reusing the aLoRA's *pre-activation* blocks:
    # the base model extends from prompt_len using k1/v1 (identical to the
    # aLoRA's pre-activation KV), generating a few tokens.
    decode_tokens = []
    cur_tokens = prompt + [y]
    k, v = k1, v1
    logits = None
    for _ in range(4):
        logits, k, v = model.run_step(
            params, cfg, cur_tokens, k, v, len(cur_tokens) - 1,
            len(cur_tokens), inv_start=cfg.max_seq_len, adapter_id=None,
        )
        nxt = int(jnp.argmax(logits))
        decode_tokens.append(nxt)
        cur_tokens.append(nxt)

    def head(x, n=16):
        return [float(t) for t in jnp.asarray(x)[:n]]

    return {
        "prompt": prompt,
        "prompt_len": prompt_len,
        "adapter_id": adapter_id,
        "invocation_tokens": inv,
        "base_next_token": y,
        "eval_tokens": eval_tokens,
        "inv_start": inv_start,
        "logits_head_n": 16,
        "base_logits_head": head(base_logits),
        "alora_full_logits_head": head(full_logits),
        "alora_reuse_logits_head": head(reuse_logits),
        "lora_logits_head": head(lora_logits),
        "alora_argmax": int(jnp.argmax(full_logits)),
        "lora_argmax": int(jnp.argmax(lora_logits)),
        "base_decode_tokens": decode_tokens,
        "final_base_logits_head": head(logits),
        "atol": 2e-3,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tile-tokens", type=int, default=None,
                    help="override L1 token-tile (perf sweep; see "
                         "EXPERIMENTS.md §Perf)")
    ap.add_argument("--tile-out", type=int, default=None,
                    help="override L1 output-feature tile")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = TINY
    if args.tile_tokens or args.tile_out:
        import dataclasses
        cfg = dataclasses.replace(
            cfg,
            tile_tokens=args.tile_tokens or cfg.tile_tokens,
            tile_out=args.tile_out or cfg.tile_out,
        )
    params = model.init_params(cfg)

    hlo = to_hlo_text(lower_step(params, cfg))
    hlo_path = os.path.join(args.out_dir, "tiny_step.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)
    print(f"wrote {hlo_path} ({len(hlo)/1e6:.1f} MB, "
          f"{cfg.param_count()/1e6:.2f}M params baked in)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest(cfg), f, indent=2)
    print("wrote manifest.json")

    golden = build_golden(params, cfg)
    with open(os.path.join(args.out_dir, "golden.json"), "w") as f:
        json.dump(golden, f, indent=2)
    print("wrote golden.json")


if __name__ == "__main__":
    main()
