"""L2: the JAX model — a functional KV-in/KV-out transformer `step`.

One executable serves every phase of the request lifecycle (DESIGN.md §9):

    step(tokens, k_in, v_in, start, length, mask_pre, adapter_onehot)
        -> (logits_at_length_minus_1, k_out, v_out)

  * fresh prefill:            start = 0,          length = prompt_len
  * cache-extension prefill:  start = cached_len, length = total_len
        — THE cross-model-reuse path: k_in/v_in carry blocks prefilled by
          the base model (or another aLoRA), and only [start, length) is
          recomputed. Positions outside the window pass K/V through
          untouched, so cache reuse is observable in the numerics.
  * decode:                   start = length - 1

aLoRA semantics (paper §2.3): `mask_pre[t] = 1` marks tokens *before* the
invocation point — their Q/K/V use the frozen base weights only, making
their K/V bit-identical to the base model's. `mask_pre = 1` everywhere is
the base model; `mask_pre = 0` everywhere is a standard LoRA (the paper's
baseline, which adapts every token and therefore cannot reuse base cache).
`adapter_onehot` selects one of the baked adapter weight sets (all-zero =
base model).

The Q/K/V projections go through the L1 Pallas kernel (kernels.alora_qkv);
attention goes through kernels.attention. `step_ref` is the pure-jnp twin
used as the end-to-end oracle in pytest.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import TinyConfig, TINY
from .kernels import ref
from .kernels.alora_qkv import alora_qkv
from .kernels.attention import attention


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: TinyConfig = TINY):
    """Deterministic parameter pytree (baked into the HLO as constants).

    Weight values are irrelevant to serving performance (paper §4.1 uses
    random adapters/inputs); determinism is what matters so that the golden
    outputs exported by aot.py stay valid for the rust integration tests.
    """
    key = jax.random.PRNGKey(cfg.seed)
    ks = iter(jax.random.split(key, 16 + 16 * cfg.n_layers))
    d, dff, r = cfg.d_model, cfg.d_ff, cfg.rank

    def dense(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale)

    params = {
        "embed": dense(next(ks), (cfg.vocab_size, d), 0.02),
        "pos_embed": dense(next(ks), (cfg.max_seq_len, d), 0.02),
        "final_norm": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        layer = {
            "norm1": jnp.ones((d,), jnp.float32),
            "norm2": jnp.ones((d,), jnp.float32),
            "wq": dense(next(ks), (d, d), d ** -0.5),
            "wk": dense(next(ks), (d, d), d ** -0.5),
            "wv": dense(next(ks), (d, d), d ** -0.5),
            "wo": dense(next(ks), (d, d), d ** -0.5),
            "w1": dense(next(ks), (d, dff), d ** -0.5),
            "w2": dense(next(ks), (dff, d), dff ** -0.5),
            # Adapter stacks: [n_adapters, ...]. a/b per projection, as in
            # the paper's ΔQ/ΔK/ΔV formulation (§2.2–2.3).
            "aq": dense(next(ks), (cfg.n_adapters, d, r), d ** -0.5),
            "bq": dense(next(ks), (cfg.n_adapters, r, d), r ** -0.5),
            "ak": dense(next(ks), (cfg.n_adapters, d, r), d ** -0.5),
            "bk": dense(next(ks), (cfg.n_adapters, r, d), r ** -0.5),
            "av": dense(next(ks), (cfg.n_adapters, d, r), d ** -0.5),
            "bv": dense(next(ks), (cfg.n_adapters, r, d), r ** -0.5),
        }
        params["layers"].append(layer)
    return params


def kv_shape(cfg: TinyConfig = TINY):
    """[L, S, H, Dh] — the KV buffer shape the rust runtime manages."""
    return (cfg.n_layers, cfg.max_seq_len, cfg.n_heads, cfg.head_dim)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _rms_norm(x, g, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def _attn_bias(cfg, length):
    """[S, S] additive mask: position i attends to j iff j <= i and j < length."""
    s = cfg.max_seq_len
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    allowed = (cols <= rows) & (cols < length)
    return jnp.where(allowed, 0.0, -1e30).astype(jnp.float32)


def _select_adapter(stack, onehot):
    """[NA, ...] stack × [NA] one-hot -> [...]; all-zero one-hot -> zeros."""
    return jnp.tensordot(onehot, stack, axes=1)


def _step_impl(params, cfg, tokens, k_in, v_in, start, length, mask_pre,
               adapter_onehot, *, use_pallas):
    s, d, h, dh = cfg.max_seq_len, cfg.d_model, cfg.n_heads, cfg.head_dim
    pos = jnp.arange(s)
    # Update window: positions whose K/V this call recomputes.
    upd = (pos >= start) & (pos < length)
    gate = (1.0 - mask_pre).astype(jnp.float32)[:, None]       # [S,1]
    bias = _attn_bias(cfg, length)
    scale = dh ** -0.5

    def proj(x, w, a_stack, b_stack):
        a = _select_adapter(a_stack, adapter_onehot)
        b = _select_adapter(b_stack, adapter_onehot)
        if use_pallas:
            return alora_qkv(x, w, a, b, gate,
                             tile_tokens=cfg.tile_tokens, tile_out=cfg.tile_out)
        return ref.alora_qkv_ref(x, w, a, b, gate)

    x = params["embed"][tokens] + params["pos_embed"]
    k_out, v_out = [], []
    for li, layer in enumerate(params["layers"]):
        xn = _rms_norm(x, layer["norm1"], cfg.rms_eps)
        q = proj(xn, layer["wq"], layer["aq"], layer["bq"])
        k = proj(xn, layer["wk"], layer["ak"], layer["bk"])
        v = proj(xn, layer["wv"], layer["av"], layer["bv"])
        q = q.reshape(s, h, dh)
        k = k.reshape(s, h, dh)
        v = v.reshape(s, h, dh)
        # KV pass-through outside [start, length): reused cache enters here.
        k_eff = jnp.where(upd[:, None, None], k, k_in[li])
        v_eff = jnp.where(upd[:, None, None], v, v_in[li])
        k_out.append(k_eff)
        v_out.append(v_eff)
        qh = jnp.transpose(q, (1, 0, 2))      # [H,S,Dh]
        kh = jnp.transpose(k_eff, (1, 0, 2))
        vh = jnp.transpose(v_eff, (1, 0, 2))
        if use_pallas:
            attn = attention(qh, kh, vh, bias, scale=scale, tile_q=cfg.tile_tokens)
        else:
            attn = ref.attention_ref(qh, kh, vh, bias, scale)
        attn = jnp.transpose(attn, (1, 0, 2)).reshape(s, d)
        x = x + attn @ layer["wo"]
        xn2 = _rms_norm(x, layer["norm2"], cfg.rms_eps)
        x = x + jax.nn.gelu(xn2 @ layer["w1"]) @ layer["w2"]

    x = _rms_norm(x, params["final_norm"], cfg.rms_eps)
    # LM head only at the last valid position (tied embedding).
    x_last = jax.lax.dynamic_slice(x, (length - 1, 0), (1, d))[0]
    logits = x_last @ params["embed"].T
    return logits, jnp.stack(k_out), jnp.stack(v_out)


def step(params, cfg, tokens, k_in, v_in, start, length, mask_pre,
         adapter_onehot):
    """Pallas-kernel forward. See module docstring for the contract."""
    return _step_impl(params, cfg, tokens, k_in, v_in, start, length,
                      mask_pre, adapter_onehot, use_pallas=True)


def step_ref(params, cfg, tokens, k_in, v_in, start, length, mask_pre,
             adapter_onehot):
    """Pure-jnp oracle — identical contract, no Pallas."""
    return _step_impl(params, cfg, tokens, k_in, v_in, start, length,
                      mask_pre, adapter_onehot, use_pallas=False)


# ---------------------------------------------------------------------------
# Convenience drivers (used by tests and by aot.py golden generation)
# ---------------------------------------------------------------------------

def empty_kv(cfg: TinyConfig = TINY):
    shape = kv_shape(cfg)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def pad_tokens(cfg: TinyConfig, tokens):
    out = jnp.zeros((cfg.max_seq_len,), jnp.int32)
    return out.at[: len(tokens)].set(jnp.asarray(tokens, jnp.int32))


def mask_for(cfg: TinyConfig, inv_start):
    """mask_pre for an aLoRA activated at absolute position `inv_start`.

    inv_start >= max_seq_len  -> all-pre (base model behaviour)
    inv_start == 0            -> standard LoRA behaviour (adapt everything)
    """
    return (jnp.arange(cfg.max_seq_len) < inv_start).astype(jnp.float32)


def onehot_for(cfg: TinyConfig, adapter_id):
    """adapter_id None -> base model (all zeros)."""
    oh = jnp.zeros((cfg.n_adapters,), jnp.float32)
    if adapter_id is None:
        return oh
    return oh.at[adapter_id].set(1.0)


@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas"))
def _jitted_step(params, cfg, tokens, k_in, v_in, start, length, mask_pre,
                 adapter_onehot, use_pallas):
    return _step_impl(params, cfg, tokens, k_in, v_in, start, length,
                      mask_pre, adapter_onehot, use_pallas=use_pallas)


def run_step(params, cfg, tokens, k, v, start, length, inv_start, adapter_id,
             use_pallas=False):
    """Ergonomic wrapper: scalars/lists in, jitted step out."""
    return _jitted_step(
        params, cfg, pad_tokens(cfg, tokens), k, v,
        jnp.int32(start), jnp.int32(length),
        mask_for(cfg, inv_start), onehot_for(cfg, adapter_id),
        use_pallas,
    )
