# Tier-1 gate (mirrors .github/workflows/ci.yml): make check
# fmt + clippy are advisory in both (leading `-`) until a toolchain-run
# `make fmt` / clippy pass lands — the repo was authored offline without
# rustfmt/clippy; see ROADMAP.md "Lint debt".
.PHONY: check build test fmt fmt-check clippy bench artifacts

check: build test
	-cargo fmt --check
	-cargo clippy --all-targets -- -D warnings

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt

fmt-check:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Hot-path microbenches (coordinator dispatch, hashing, scheduler, ...)
bench:
	cargo bench --bench bench_hotpath

# AOT-compile the tiny model + goldens for the real-runtime path
# (requires JAX; see DESIGN.md §9).
artifacts:
	python3 python/compile/aot.py
