# Tier-1 gate (mirrors .github/workflows/ci.yml): make check
# fmt + clippy are advisory in both (leading `-`) until a toolchain-run
# `make fmt` / clippy pass lands — the repo was authored offline without
# rustfmt/clippy (still true as of 2026-08-08, PR 9); see ROADMAP.md
# "Lint debt".
.PHONY: check build build-matrix test fmt fmt-check clippy bench bench-smoke bench-lint server-smoke artifacts

check: build test
	-cargo fmt --check
	-cargo clippy --all-targets -- -D warnings

# Feature matrix (mirrors CI): the offline default, explicitly
# no-default-features, and a check-only pass of the real-runtime feature
# (advisory: it needs the external `xla` crate, absent offline).
build-matrix: build
	cargo build --release --no-default-features
	-cargo check --features real-runtime

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt

fmt-check:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Hot-path microbenches (coordinator dispatch, hashing, scheduler, ...)
bench:
	cargo bench --bench bench_hotpath

# Fast end-to-end smoke over the fleet + memory-budget + failover paths:
# the cluster bench on its quick grid, the adapter-memory figure, the
# failover figure (kill 1 of 4 replicas mid-burst) in quick mode, the
# migration figure (migrate-vs-recompute TTFT sweep + fork fan-out) in
# quick mode, the self-driving figure (silenced-replica detection +
# diurnal autoscale) in quick mode, the session-scale harness at its
# quick tier (10^5 concurrent sessions — writes BENCH_scale.json at the
# repo root; CI uploads it and diffs the p99 TTFT against the committed
# baseline, advisory), the handler-contention harness at its quick tier
# (1..=8 client threads over real HTTP — writes BENCH_concurrency.json;
# CI diffs only its deterministic session/turn counts), the migration
# harness (writes BENCH_migration.json; CI diffs the long-prefix
# speedup, advisory), the self-driving harness (writes
# BENCH_selfdriving.json; CI diffs detection latency and recovered
# hit-rate, advisory), and the adapter-tiering harness (writes
# BENCH_adapter_tiering.json; CI diffs the prefetch stall reduction and
# the fleet hit-rates, advisory).
bench-smoke:
	cargo bench --bench bench_cluster -- --quick
	cargo run --release -- figure --id adapter_memory --quick
	cargo run --release -- figure --id adapter_tiering --quick
	cargo run --release -- figure --id failover --quick
	cargo run --release -- figure --id migration --quick
	cargo run --release -- figure --id selfdriving --quick
	cargo bench --bench bench_scale -- --quick
	cargo bench --bench bench_concurrency -- --quick
	cargo bench --bench bench_migration -- --quick
	cargo bench --bench bench_selfdriving -- --quick
	cargo bench --bench bench_adapter_tiering -- --quick

# Schema lint for the committed bench baselines: every BENCH_*.json in
# HEAD must be a JSON object carrying the shared keys the CI diff steps
# rely on, plus a boolean `offline_estimate` provenance flag (the
# committed baselines were authored without a toolchain; drop the flag
# — and this check — once real runs replace them). Reads the committed
# copies, so it is safe to run after bench-smoke has overwritten the
# working tree. Advisory if jq is absent.
bench-lint:
	@if ! command -v jq >/dev/null; then echo "jq not installed; skipping"; exit 0; fi; \
	for f in $$(git ls-files 'BENCH_*.json'); do \
		git show HEAD:$$f | jq -e 'type == "object" and has("bench") and has("quick") \
			and has("note") and (.offline_estimate | type == "boolean")' >/dev/null \
			|| { echo "$$f: missing required bench keys"; exit 1; }; \
		echo "$$f: ok"; \
	done

# HTTP surface smoke (mirrors the CI step): the HTTP integration suite
# plus the v1 sessions suite, which includes the streaming smoke
# (session create → 3 streaming delta turns → delete).
server-smoke:
	cargo test -q --test server_http
	cargo test -q --test sessions_api

# AOT-compile the tiny model + goldens for the real-runtime path
# (requires JAX; see DESIGN.md §9).
artifacts:
	python3 python/compile/aot.py
