# Tier-1 gate (mirrors .github/workflows/ci.yml): make check
# fmt is advisory in both (leading `-`) until a toolchain-run `make fmt`
# lands — the repo was authored offline without rustfmt; see CHANGES.md.
.PHONY: check build test fmt fmt-check bench artifacts

check: build test
	-cargo fmt --check

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt

fmt-check:
	cargo fmt --check

# Hot-path microbenches (coordinator dispatch, hashing, scheduler, ...)
bench:
	cargo bench --bench bench_hotpath

# AOT-compile the tiny model + goldens for the real-runtime path
# (requires JAX; see DESIGN.md §9).
artifacts:
	python3 python/compile/aot.py
