//! Figure 11 (Appendix C) reproduction: adapter-base pipeline — the
//! reverse reuse direction (base consumes adapter-prefilled blocks).

use std::time::Instant;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let t0 = Instant::now();
    alora_serve::figures::fig11::run(quick).print();
    println!("\n[bench_fig11 completed in {:.1}s]", t0.elapsed().as_secs_f64());
}
