//! `bench_adapter_tiering`: tiered adapter memory (ISSUE 10).
//!
//! Runs the `adapter_tiering` figure — the churn sweep (drop vs host-tier
//! demotion vs prefetch vs the zero-cost baseline) and the equal-budget
//! heterogeneous-vs-homogeneous fleet comparison — and writes
//! `BENCH_adapter_tiering.json` at the repo root. CI runs the `--quick`
//! tier, uploads the report, and diffs the headline ratios against the
//! committed baseline (advisory only; virtual-time results are seeded and
//! deterministic, so a real diff means a real behavior change).

use alora_serve::figures::adapter_tiering::{run_churn, run_fleet, LOAD_BW};
use alora_serve::util::bench::section;
use alora_serve::util::json::Json;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    section(&format!(
        "adapter tiering harness: churn arms + fleet packing ({})",
        if quick { "quick tier" } else { "full tier" }
    ));
    let t0 = std::time::Instant::now();
    let table = alora_serve::figures::adapter_tiering::run(quick);
    table.print();

    // Headline ratios re-measured at this tier's sizes (same runners the
    // figure rows came from; the rerun keeps the JSON self-contained).
    let n_requests = if quick { 9 } else { 18 };
    let rounds = if quick { 4 } else { 8 };
    let plain = run_churn(96, LOAD_BW, false, n_requests);
    let prefetch = run_churn(96, LOAD_BW, true, n_requests);
    let drop = run_churn(0, LOAD_BW, false, n_requests);
    let hetero = run_fleet(true, rounds);
    let homo = run_fleet(false, rounds);
    let wall_s = t0.elapsed().as_secs_f64();

    let stall_reduction = plain.stall_steps.saturating_sub(prefetch.stall_steps);
    let demote_speedup = drop.makespan / plain.makespan;
    println!(
        "\nprefetch: {} -> {} stall steps (-{stall_reduction}); \
         demote vs drop makespan: {:.4}s vs {:.4}s ({demote_speedup:.3}x); \
         fleet hit-rate hetero {:.3} vs homo {:.3}",
        plain.stall_steps,
        prefetch.stall_steps,
        plain.makespan,
        drop.makespan,
        hetero.aggregate_adapter_hit_rate,
        homo.aggregate_adapter_hit_rate
    );

    let report = Json::obj(vec![
        ("bench", Json::str("adapter_tiering")),
        ("quick", Json::Bool(quick)),
        ("wall_s", Json::num(wall_s)),
        ("stall_steps_plain", Json::num(plain.stall_steps as f64)),
        ("stall_steps_prefetch", Json::num(prefetch.stall_steps as f64)),
        ("prefetch_stall_reduction", Json::num(stall_reduction as f64)),
        ("makespan_drop_s", Json::num(drop.makespan)),
        ("makespan_demote_s", Json::num(plain.makespan)),
        ("demote_reload_speedup", Json::num(demote_speedup)),
        ("hetero_hit_rate", Json::num(hetero.aggregate_adapter_hit_rate)),
        ("homo_hit_rate", Json::num(homo.aggregate_adapter_hit_rate)),
        (
            "note",
            Json::str(
                "seeded virtual-time run; regenerate with \
                 `cargo bench --bench bench_adapter_tiering -- --quick` \
                 (make bench-smoke)",
            ),
        ),
    ]);
    std::fs::write("BENCH_adapter_tiering.json", format!("{report}\n"))
        .expect("write BENCH_adapter_tiering.json");
    println!("wrote BENCH_adapter_tiering.json");
}
