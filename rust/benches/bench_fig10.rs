//! Figure 10 reproduction: base-adapter-base generation-length sweep +
//! 5-parallel-adapter variant with the base2 queuing-damage table.

use std::time::Instant;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let t0 = Instant::now();
    for table in alora_serve::figures::fig10::run(quick) {
        table.print();
    }
    println!("\n[bench_fig10 completed in {:.1}s]", t0.elapsed().as_secs_f64());
}
