//! Figure 8 reproduction: async base-adapter pipeline, Poisson arrival
//! rate sweep (n=500 unless QUICK=1).

use std::time::Instant;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let t0 = Instant::now();
    alora_serve::figures::fig8::run(quick).print();
    println!("\n[bench_fig8 completed in {:.1}s]", t0.elapsed().as_secs_f64());
}
