//! `bench_migration`: migrate-vs-recompute and fork fan-out (ISSUE 8).
//!
//! Runs the `migration` figure's two sweeps — post-failover next-turn
//! TTFT across prefix lengths with cross-replica block migration on vs
//! off, and K-way session forking vs K independent sessions — and
//! writes `BENCH_migration.json` at the repo root. CI runs the `--quick`
//! tier, uploads the report, and diffs the long-prefix migration speedup
//! against the committed baseline (advisory only; virtual-time results
//! are seeded and deterministic, so a real diff means a real behavior
//! change).

use alora_serve::figures::migration::run_curve;
use alora_serve::util::bench::section;
use alora_serve::util::json::Json;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    section(&format!(
        "migration harness: prefix sweep + fork fan-out ({})",
        if quick { "quick tier" } else { "full tier" }
    ));
    let t0 = std::time::Instant::now();
    let curve = run_curve(quick);
    let wall_s = t0.elapsed().as_secs_f64();
    curve.table.print();

    let long = curve.failover.last().expect("at least one prefix point");
    let speedup = long.ttft_recompute / long.ttft_migrate;
    println!(
        "\nlong-prefix ({} tokens): migrate {:.4}s vs recompute {:.4}s — {speedup:.2}x",
        long.prefix_tokens, long.ttft_migrate, long.ttft_recompute
    );

    let failover = curve
        .failover
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("prefix_tokens", Json::num(p.prefix_tokens as f64)),
                ("ttft_migrate_s", Json::num(p.ttft_migrate)),
                ("ttft_recompute_s", Json::num(p.ttft_recompute)),
                ("migrated_blocks", Json::num(p.migrated_blocks as f64)),
            ])
        })
        .collect();
    let fork = curve
        .fork
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("k", Json::num(p.k as f64)),
                ("ttft_forked_s", Json::num(p.ttft_forked)),
                ("ttft_independent_s", Json::num(p.ttft_independent)),
                ("new_blocks_forked", Json::num(p.blocks_forked as f64)),
                ("new_blocks_independent", Json::num(p.blocks_independent as f64)),
            ])
        })
        .collect();

    let report = Json::obj(vec![
        ("bench", Json::str("migration")),
        ("quick", Json::Bool(quick)),
        ("wall_s", Json::num(wall_s)),
        ("long_prefix_speedup", Json::num(speedup)),
        ("failover", Json::Arr(failover)),
        ("fork", Json::Arr(fork)),
        (
            "note",
            Json::str(
                "seeded virtual-time run; regenerate with \
                 `cargo bench --bench bench_migration -- --quick` (make bench-smoke)",
            ),
        ),
    ]);
    std::fs::write("BENCH_migration.json", format!("{report}\n"))
        .expect("write BENCH_migration.json");
    println!("wrote BENCH_migration.json");
}
