//! `bench_scale`: the million-session serving harness (ISSUE 6).
//!
//! `--quick` drives 10^5 concurrent sessions through the fleet (the CI
//! `make bench-smoke` tier); the default tier drives 10^6. Bursty
//! diurnal-mixture Poisson arrivals, p50/p99 TTFT and ITL at the
//! serving boundary, per-turn placement cost in concrete ops, and the
//! peak memory ceilings (KV blocks, session table, bounded metrics
//! reservoirs). Writes `BENCH_scale.json` at the repo root — CI uploads
//! it and diffs the p99 TTFT against the committed baseline
//! (advisory only; virtual-time results are seeded and deterministic,
//! so a real diff means a real behavior change).

use alora_serve::figures::scale::{run_harness, ScaleConfig};
use alora_serve::util::bench::section;
use alora_serve::util::json::Json;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { ScaleConfig::quick_bench() } else { ScaleConfig::full_bench() };
    section(&format!(
        "scale harness: {} concurrent sessions, {} follow-up turns ({})",
        cfg.sessions,
        cfg.followups,
        if quick { "quick tier" } else { "full tier" }
    ));
    let t0 = std::time::Instant::now();
    let mut r = run_harness(&cfg);
    let wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(r.final_sessions, 0, "TTL sweep left sessions behind");

    let ttft_p50 = r.ttft.percentile(50.0);
    let ttft_p99 = r.ttft.p99();
    let itl_p50 = r.itl.percentile(50.0);
    let itl_p99 = r.itl.p99();
    println!(
        "turns {}  virtual {:.1}s  wall {:.1}s  ({:.0} turns/wall-s)",
        r.turns,
        r.virtual_s,
        wall_s,
        r.turns as f64 / wall_s.max(1e-9)
    );
    println!("TTFT p50 {:.4}s  p99 {:.4}s", ttft_p50, ttft_p99);
    println!("ITL  p50 {:.5}s  p99 {:.5}s", itl_p50, itl_p99);
    println!(
        "placement cost/turn: {:.2} hash ops, {:.2} probe ops",
        r.hash_ops_per_turn(),
        r.probe_ops_per_turn()
    );
    println!(
        "ceilings: {} sessions, {} KV blocks, {} retained metric samples; {} expired",
        r.peak_sessions, r.peak_blocks, r.metrics_retained, r.expired
    );

    let report = Json::obj(vec![
        ("bench", Json::str("scale")),
        ("quick", Json::Bool(quick)),
        ("seed", Json::num(cfg.seed as f64)),
        ("sessions", Json::num(r.sessions as f64)),
        ("turns", Json::num(r.turns as f64)),
        ("replicas", Json::num(cfg.replicas as f64)),
        ("virtual_s", Json::num(r.virtual_s)),
        ("wall_s", Json::num(wall_s)),
        (
            "ttft_s",
            Json::obj(vec![("p50", Json::num(ttft_p50)), ("p99", Json::num(ttft_p99))]),
        ),
        (
            "itl_s",
            Json::obj(vec![("p50", Json::num(itl_p50)), ("p99", Json::num(itl_p99))]),
        ),
        (
            "placement_cost",
            Json::obj(vec![
                ("hash_ops_per_turn", Json::num(r.hash_ops_per_turn())),
                ("probe_ops_per_turn", Json::num(r.probe_ops_per_turn())),
            ]),
        ),
        (
            "memory_ceiling",
            Json::obj(vec![
                ("peak_sessions", Json::num(r.peak_sessions as f64)),
                ("peak_kv_blocks", Json::num(r.peak_blocks as f64)),
                ("metrics_retained_samples", Json::num(r.metrics_retained as f64)),
            ]),
        ),
        ("sessions_expired", Json::num(r.expired as f64)),
        (
            "note",
            Json::str(
                "seeded virtual-time run; regenerate with \
                 `cargo bench --bench bench_scale -- --quick` (make bench-smoke)",
            ),
        ),
    ]);
    std::fs::write("BENCH_scale.json", format!("{report}\n")).expect("write BENCH_scale.json");
    println!("wrote BENCH_scale.json");
}
