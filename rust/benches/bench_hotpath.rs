//! Hot-path microbenches — the §Perf iteration loop's instrument.
//!
//! Covers every L3 operation on the engine's per-step critical path:
//! chained block hashing, prefix matching, block alloc/free, admission,
//! scheduler step packing, mask building, and the end-to-end sim step.
//! Before/after numbers for each optimization are recorded in
//! EXPERIMENTS.md §Perf.

use alora_serve::util::fxmap::FxHashMap;

use alora_serve::config::presets;
use alora_serve::engine::{build_batch_mask, Engine};
use alora_serve::kvcache::manager::KvCacheManager;
use alora_serve::kvcache::prefix::{block_hashes, HashContext};
use alora_serve::pipeline::workload;
use alora_serve::request::{ModelTarget, Request, RequestId, SamplingParams};
use alora_serve::scheduler::Scheduler;
use alora_serve::simulator::SimExecutor;
use alora_serve::util::bench::{bench, black_box, section};
use alora_serve::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);

    section("block hashing");
    let tokens_4k = rng.tokens(4096, 49155, 64);
    let tokens_64k = rng.tokens(65536, 49155, 64);
    let ctx = HashContext::base();
    println!("{}", bench("hash chain, 4k tokens (256 blocks)", || {
        black_box(block_hashes(&tokens_4k, 16, &ctx))
    }));
    println!("{}", bench("hash chain, 64k tokens (4096 blocks)", || {
        black_box(block_hashes(&tokens_64k, 16, &ctx))
    }));
    let alora_ctx = HashContext {
        adapter_id: Some(1),
        is_alora: true,
        inv_start: 4000,
        base_aligned: true,
        cache_salt: 0,
    };
    println!("{}", bench("hash chain, 4k tokens, aLoRA salting", || {
        black_box(block_hashes(&tokens_4k, 16, &alora_ctx))
    }));

    section("kv-cache manager");
    let hashes = block_hashes(&tokens_4k, 16, &ctx);
    println!("{}", bench("admission miss + alloc + commit + free (4k)", || {
        let mut kv = KvCacheManager::new(512, 16, true);
        kv.start_request(1, &hashes, 4096);
        assert!(kv.ensure_capacity(1, 4096));
        kv.commit_full_blocks(1, &hashes);
        kv.free_request(1);
    }));
    {
        let mut kv = KvCacheManager::new(512, 16, true);
        kv.start_request(1, &hashes, 4096);
        assert!(kv.ensure_capacity(1, 4096));
        kv.commit_full_blocks(1, &hashes);
        kv.free_request(1);
        let mut next = 2u64;
        println!("{}", bench("warm admission (full 256-block hit) + free", || {
            let key = next;
            next += 1;
            let c = kv.start_request(key, &hashes, 4096);
            assert_eq!(c.blocks, 256);
            kv.free_request(key);
        }));
        println!("{}", bench("peek cached prefix (hit, 256 blocks)", || {
            black_box(kv.peek_cached_prefix(&hashes))
        }));
    }

    section("scheduler");
    {
        let cfg = presets::granite_8b();
        let mut sched = Scheduler::new(cfg.scheduler.clone());
        let mut kv = KvCacheManager::new(cfg.cache.num_blocks() as u32, 16, true);
        let mut reqs: FxHashMap<RequestId, Request> = FxHashMap::default();
        // 64 decoding requests, steady state.
        for i in 0..64u64 {
            let mut r = Request::new(
                RequestId(i),
                ModelTarget::Base,
                rng.tokens(512, 49155, 64),
                SamplingParams { max_new_tokens: 1000, ..Default::default() },
                0.0,
            );
            r.hash_ctx = HashContext::base();
            reqs.insert(r.id, r);
            sched.enqueue(RequestId(i), false);
        }
        let mut residency = alora_serve::adapter::AdapterResidency::disabled();
        // Drain prefill so everything decodes.
        for _ in 0..64 {
            let s = sched.schedule(&mut reqs, &mut kv, &mut residency);
            for sq in &s.seqs {
                let r = reqs.get_mut(&sq.id).unwrap();
                r.num_computed_tokens = sq.chunk_start + sq.chunk_len;
                if sq.produces_token {
                    r.output_tokens.push(1);
                }
            }
        }
        println!("{}", bench("schedule() 64-seq decode steady state", || {
            let s = sched.schedule(&mut reqs, &mut kv, &mut residency);
            for sq in &s.seqs {
                let r = reqs.get_mut(&sq.id).unwrap();
                r.num_computed_tokens = sq.chunk_start + sq.chunk_len;
                if sq.produces_token {
                    r.output_tokens.push(1);
                }
            }
            black_box(s.total_tokens)
        }));

        let seqs: Vec<_> = reqs
            .values()
            .take(64)
            .map(|r| alora_serve::scheduler::ScheduledSeq {
                id: r.id,
                chunk_start: r.num_computed_tokens.max(1) - 1,
                chunk_len: 1,
                produces_token: true,
                is_decode: true,
            })
            .collect();
        println!("{}", bench("build_batch_mask 64-seq decode", || {
            black_box(build_batch_mask(&seqs, &reqs))
        }));
    }

    section("end-to-end sim engine step");
    {
        let cfg = presets::granite_8b();
        let reg = workload::build_registry(1, cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&cfg);
        let mut engine = Engine::with_registry(cfg, reg, exec);
        let mut rng = Rng::new(3);
        for _ in 0..32 {
            engine
                .submit(
                    ModelTarget::Base,
                    rng.tokens(1024, 49155, 64),
                    SamplingParams { max_new_tokens: 100_000, ..Default::default() },
                )
                .unwrap();
        }
        // prefill out of the way
        for _ in 0..40 {
            engine.step();
        }
        println!("{}", bench("engine.step() 32-seq decode (granite-8b sim)", || {
            black_box(engine.step())
        }));
    }

    section("coordinator dispatch (B=64 conversations, 4-stage DAG)");
    {
        use alora_serve::adapter::AdapterId;
        use alora_serve::coordinator::{Coordinator, StageGraph, StageId};

        let cfg = presets::granite_8b();
        let reg = workload::build_registry(2, cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&cfg);
        let mut engine = Engine::with_registry(cfg, reg, exec);
        let vocab = engine.cfg.model.vocab_size;
        let mut rng = Rng::new(11);
        let build = |rng: &mut Rng, vocab: u32| -> StageGraph {
            let mut g = StageGraph::new();
            let draft = g.root(
                "draft",
                ModelTarget::Base,
                rng.tokens(256, vocab, 64),
                32,
            );
            let evals: Vec<StageId> = (0..2)
                .map(|a| {
                    g.chain(
                        &format!("eval-{a}"),
                        ModelTarget::Adapter(AdapterId(a)),
                        draft,
                        workload::invocation_for(vocab, a),
                        8,
                    )
                })
                .collect();
            g.consolidate("consolidate", ModelTarget::Base, draft, &evals, Vec::new(), 8);
            g
        };
        // Graph construction + composition cost, isolated from the engine.
        println!("{}", bench("StageGraph build (4 stages)", || {
            black_box(build(&mut rng, vocab).len())
        }));
        // End-to-end event drive: wall time per stage is the coordinator's
        // dispatch overhead on top of the (virtual-time) sim engine. Fresh
        // seed: `rng` was consumed an adaptive number of times by bench()
        // above, and the §Perf makespan baseline must be reproducible.
        let mut rng = Rng::new(12);
        let graphs: Vec<StageGraph> =
            (0..64).map(|_| build(&mut rng, vocab)).collect();
        let n_stages: usize = graphs.iter().map(|g| g.len()).sum();
        let arrivals = vec![0.0; graphs.len()];
        let t0 = std::time::Instant::now();
        let r = Coordinator::run_event(&mut engine, graphs, &arrivals)
            .expect("bench coordinator run");
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(r.outputs.len(), n_stages);
        println!(
            "coordinator event drive: {} stages, B=64: wall {:.3}s ({:.1} µs/stage, virtual makespan {:.3}s)",
            n_stages,
            wall,
            wall / n_stages as f64 * 1e6,
            r.makespan
        );
    }

    section("full pipeline wall-clock (sim)");
    {
        let t0 = std::time::Instant::now();
        let spec = alora_serve::pipeline::PipelineSpec::base_adapter(1024, 128, 16);
        let mut e = {
            let cfg = presets::granite_8b();
            let reg = workload::build_registry(1, cfg.model.vocab_size, true);
            let exec = SimExecutor::new(&cfg);
            Engine::with_registry(cfg, reg, exec)
        };
        let r = alora_serve::pipeline::run_sync(&mut e, &spec, 16, 42);
        println!(
            "base-adapter sync, batch 16, prompt 1k: wall {:.3}s for {} reqs (virtual makespan {:.3}s)",
            t0.elapsed().as_secs_f64(),
            r.outputs.len(),
            r.makespan
        );
    }
}
