//! Figures 13–14 (Appendix E) reproduction: async base+eval step —
//! aggregate metrics and stage breakdown vs arrival rate.

use std::time::Instant;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let t0 = Instant::now();
    for table in alora_serve::figures::fig13_14::run(quick) {
        table.print();
    }
    println!("\n[bench_fig13_14 completed in {:.1}s]", t0.elapsed().as_secs_f64());
}
