//! Figure 9 reproduction: speedup vs arrival rate × generation length,
//! plus the cache-overflow probe showing reuse collapse past capacity.

use std::time::Instant;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let t0 = Instant::now();
    alora_serve::figures::fig9::run(quick).print();
    let (small, big) = alora_serve::figures::fig9::overflow_probe();
    println!(
        "\ncache-overflow probe: hit rate {:.2} (16k-token cache) vs {:.2} (full cache)",
        small, big
    );
    println!("[bench_fig9 completed in {:.1}s]", t0.elapsed().as_secs_f64());
}
