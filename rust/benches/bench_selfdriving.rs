//! `bench_selfdriving`: unattended failure detection + autoscaling (ISSUE 9).
//!
//! Runs the `selfdriving` figure's two arms — a silenced replica walked
//! Up → Suspected → Down by the heartbeat monitor with the ordinary
//! failover pipeline evacuating it (no admin call), and a diurnal load
//! cycle driving the autoscaler up to standbys and back down to the
//! minimum — and writes `BENCH_selfdriving.json` at the repo root. CI
//! runs the `--quick` tier, uploads the report, and diffs the detection
//! latency and recovered hit-rate against the committed baseline
//! (advisory only; virtual-time results are seeded and deterministic, so
//! a real diff means a real behavior change).

use alora_serve::figures::selfdriving::run_curves;
use alora_serve::util::bench::section;
use alora_serve::util::json::Json;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    section(&format!(
        "self-driving fleet harness: detection + diurnal autoscale ({})",
        if quick { "quick tier" } else { "full tier" }
    ));
    let t0 = std::time::Instant::now();
    let curves = run_curves(quick);
    let wall_s = t0.elapsed().as_secs_f64();
    curves.detect.print();
    curves.autoscale.print();

    println!(
        "\ndetection: {} steps to declare; hit-rate dip {:.3} -> recovered {:.3}; \
         {} requeued, {}/{} turns completed",
        curves.detection_steps,
        curves.dip(),
        curves.recovered(),
        curves.requeued,
        curves.turns_completed,
        curves.turns_submitted,
    );
    println!(
        "autoscale: peak {} active, final {}; {} scale-ups / {} scale-downs; \
         {}/{} requests completed",
        curves.peak_active,
        curves.final_active,
        curves.scale_ups,
        curves.scale_downs,
        curves.reqs_completed,
        curves.reqs_submitted,
    );

    let hit_rates = curves.hit_rates.iter().map(|&h| Json::num(h)).collect();
    let report = Json::obj(vec![
        ("bench", Json::str("selfdriving")),
        ("quick", Json::Bool(quick)),
        ("wall_s", Json::num(wall_s)),
        ("detection_steps", Json::num(curves.detection_steps as f64)),
        ("dip_hit_rate", Json::num(curves.dip())),
        ("recovered_hit_rate", Json::num(curves.recovered())),
        ("hit_rates", Json::Arr(hit_rates)),
        ("requeued", Json::num(curves.requeued as f64)),
        ("turns_submitted", Json::num(curves.turns_submitted as f64)),
        ("turns_completed", Json::num(curves.turns_completed as f64)),
        ("peak_active", Json::num(curves.peak_active as f64)),
        ("final_active", Json::num(curves.final_active as f64)),
        ("scale_ups", Json::num(curves.scale_ups as f64)),
        ("scale_downs", Json::num(curves.scale_downs as f64)),
        ("reqs_submitted", Json::num(curves.reqs_submitted as f64)),
        ("reqs_completed", Json::num(curves.reqs_completed as f64)),
        (
            "note",
            Json::str(
                "seeded virtual-time run; regenerate with \
                 `cargo bench --bench bench_selfdriving -- --quick` (make bench-smoke)",
            ),
        ),
    ]);
    std::fs::write("BENCH_selfdriving.json", format!("{report}\n"))
        .expect("write BENCH_selfdriving.json");
    println!("wrote BENCH_selfdriving.json");
}
