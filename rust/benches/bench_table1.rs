//! Bench/repro target for paper Table 1: model & server configurations.
//! Prints the table and times the config/validation path.

use alora_serve::figures::table1;
use alora_serve::util::bench::{bench, section};

fn main() {
    section("Table 1 — model and server configurations");
    table1::run().print();

    section("config-path microbench");
    let r = bench("preset construction + validation", || {
        for name in alora_serve::config::presets::PRESET_NAMES {
            let c = alora_serve::config::presets::by_name(name).unwrap();
            c.validate().unwrap();
        }
    });
    println!("{r}");
}
