//! Figure 12 (Appendix D) reproduction: TTFT and inference-time breakdown
//! of the base-adapter eval step.

use std::time::Instant;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let t0 = Instant::now();
    alora_serve::figures::fig12::run(quick).print();
    println!("\n[bench_fig12 completed in {:.1}s]", t0.elapsed().as_secs_f64());
}
