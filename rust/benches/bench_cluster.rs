//! Cluster scaling bench: the fleet-level Figure-9 table at full size,
//! plus routing-decision microbenches (the per-request cost the router
//! adds to the submit path). Pass `--quick` (e.g. via `make bench-smoke`:
//! `cargo bench --bench bench_cluster -- --quick`) for the shrunk grid.

use alora_serve::cluster::router::{ReplicaView, RoutePolicy, Router, RouterConfig};
use alora_serve::figures;
use alora_serve::kvcache::prefix::{block_hashes, HashContext};
use alora_serve::kvcache::summary::HashSummary;
use alora_serve::util::bench::{bench, black_box, section};
use alora_serve::util::rng::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    section(if quick {
        "cluster scaling (quick grid)"
    } else {
        "cluster scaling (full grid)"
    });
    let t = figures::cluster_scaling::run(quick);
    t.print();

    section("routing decision microbenches");
    let mut rng = Rng::new(11);
    let tokens = rng.tokens(4096, 49_155, 64);
    let chain = block_hashes(&tokens, 16, &HashContext::base());
    let mut summary = HashSummary::new();
    for h in &chain {
        summary.insert(*h);
    }
    println!("{}", bench("hash chain for routing, 4k tokens", || {
        black_box(block_hashes(&tokens, 16, &HashContext::base()))
    }));
    println!("{}", bench("summary matching_prefix, 256-block hit", || {
        black_box(summary.matching_prefix(&chain))
    }));
    let views: Vec<ReplicaView> = (0..8)
        .map(|i| ReplicaView {
            load: i,
            affinity_blocks: 256 - i,
            adapter_blocks: 0,
            free_blocks: 0,
            healthy: true,
            suspected: false,
            warming: false,
        })
        .collect();
    let mut router = Router::new(
        RouterConfig { policy: RoutePolicy::PrefixAffinity, ..Default::default() },
        views.len(),
    );
    println!("{}", bench("router choose, 8 replicas, warm", || {
        black_box(router.choose(&views).replica)
    }));
}
