//! Figure 6 reproduction: synchronous base-adapter pipeline, prompt-length
//! sweep over all three Table-1 models, LoRA vs aLoRA, per-stage latencies
//! + speedups. `QUICK=1` shrinks the sweep (CI).

use std::time::Instant;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let t0 = Instant::now();
    for table in alora_serve::figures::fig6::run(quick) {
        table.print();
    }
    println!("\n[bench_fig6 completed in {:.1}s]", t0.elapsed().as_secs_f64());
}
