//! Figure 15 (Appendix F) reproduction: per-length KV-filling batch sizes
//! — decode times dominate short prompts, motivating the fixed-batch
//! methodology of the synchronous trials.

use std::time::Instant;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let t0 = Instant::now();
    alora_serve::figures::fig15::run(quick).print();
    println!("\n[bench_fig15 completed in {:.1}s]", t0.elapsed().as_secs_f64());
}
