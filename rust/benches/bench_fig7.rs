//! Figure 7 reproduction: eval-step token throughput @65k prompt,
//! KV-filling batch, all three models.

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    alora_serve::figures::fig7::run().print();
    println!("\n[bench_fig7 completed in {:.1}s]", t0.elapsed().as_secs_f64());
}
