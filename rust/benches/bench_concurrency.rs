//! `bench_concurrency`: the lock-split serving hot path under handler
//! contention (ISSUE 7).
//!
//! Sweeps 1..=16 client threads (`--quick`: 1..=8) against a live server
//! over real HTTP — each thread creating sessions and driving delta
//! turns — and records aggregate turn throughput and the p50/p99 TTFT
//! the clients observe. Writes `BENCH_concurrency.json` at the repo
//! root — CI uploads it and diffs only the DETERMINISTIC columns
//! (session/turn counts) against the committed baseline: wall-clock
//! throughput and TTFT-under-contention depend on the runner and are
//! informational.

use alora_serve::figures::concurrency::{run_contention, ContentionConfig};
use alora_serve::util::bench::section;
use alora_serve::util::json::Json;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (threads, per): (&[usize], usize) =
        if quick { (&[1, 2, 4, 8], 8) } else { (&[1, 2, 4, 8, 16], 16) };
    section(&format!(
        "concurrency harness: {:?} client threads x {per} sessions ({})",
        threads,
        if quick { "quick tier" } else { "full tier" }
    ));
    let mut tiers: Vec<Json> = Vec::new();
    for &n in threads {
        let cfg = ContentionConfig::sized(n, per);
        let r = run_contention(&cfg);
        assert_eq!(r.sessions, (n * per) as u64, "lost or duplicated sessions");
        assert_eq!(
            r.turns,
            (n * per * cfg.turns_per_session) as u64,
            "lost or duplicated turns"
        );
        println!(
            "{:2} threads: {} turns in {:.2}s wall  ({:.0} turns/s)  \
             TTFT p50 {:.4}s p99 {:.4}s  delta-hit {:.3}",
            n,
            r.turns,
            r.wall_s,
            r.turns_per_s(),
            r.ttft.percentile(50.0),
            r.ttft.p99(),
            r.delta_hit_rate
        );
        tiers.push(Json::obj(vec![
            ("threads", Json::num(n as f64)),
            ("sessions", Json::num(r.sessions as f64)),
            ("turns", Json::num(r.turns as f64)),
            ("wall_s", Json::num(r.wall_s)),
            ("turns_per_s", Json::num(r.turns_per_s())),
            (
                "ttft_s",
                Json::obj(vec![
                    ("p50", Json::num(r.ttft.percentile(50.0))),
                    ("p99", Json::num(r.ttft.p99())),
                ]),
            ),
            ("delta_hit_rate", Json::num(r.delta_hit_rate)),
        ]));
    }
    let report = Json::obj(vec![
        ("bench", Json::str("concurrency")),
        ("quick", Json::Bool(quick)),
        ("tiers", Json::Arr(tiers)),
        (
            "note",
            Json::str(
                "real wall-clock HTTP contention run; only sessions/turns are \
                 deterministic — regenerate with \
                 `cargo bench --bench bench_concurrency -- --quick` (make bench-smoke)",
            ),
        ),
    ]);
    std::fs::write("BENCH_concurrency.json", format!("{report}\n"))
        .expect("write BENCH_concurrency.json");
    println!("wrote BENCH_concurrency.json");
}
