//! Conversation-first serving: the [`SessionManager`] drives delta turns
//! over any [`EngineDriver`].
//!
//! This is the layer behind the v1 HTTP API (`POST /v1/sessions`,
//! `POST /v1/sessions/{id}/turns`): sessions hold the conversation state
//! ([`crate::request::session`]), the manager turns a client's **token
//! delta** into a full-chain submission and applies the serving-side
//! conventions that make the paper's reuse structural instead of
//! accidental:
//!
//! - **Delta composition** — the full prompt is history + delta, so the
//!   engine always sees the byte-identical base-aligned chain, turn after
//!   turn (and an aLoRA turn's pre-activation chain matches it).
//! - **Continuation priority** — turns enqueue at the front of the
//!   waiting queue (paper §4.3: continuations harvest their cached
//!   prefixes before eviction can claim the blocks).
//! - **Sticky placement** — turns submit with the previous turn's request
//!   id as the stickiness peer, so a cluster pins the conversation to the
//!   replica holding its prefix (first turns fall back to the routing
//!   policy, typically `PrefixAffinity`).
//! - **Prefix leases** — after each turn the session's chain is pinned
//!   (`EngineDriver::acquire_lease`), so the blocks survive between turns
//!   even under cache churn from unrelated traffic; `DELETE` releases
//!   them. Leases are best-effort: the KV manager breaks them
//!   oldest-first under allocation pressure, and a per-tenant leased-
//!   block budget (see [`SessionManager::with_limits`]) breaks a hoarding
//!   tenant's oldest leases so one tenant cannot pin the whole pool.
//! - **Per-turn metrics** — every completed turn lands in the driver's
//!   `Metrics::turn` series (TTFT / ITL at the serving boundary).
//!
//! The session table is **sharded**: sessions hash (by id) onto
//! [`SHARDS`] independently locked maps, so turn submission, expiry
//! sweeps and failover repair touching *different* sessions never
//! serialize on one table lock (DESIGN.md §17). All manager methods take
//! `&self`; a method locks at most one shard at a time (the tenant
//! ledger is a separate lock, always acquired *after* releasing shard
//! locks — never nested inside one while another shard is taken).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::engine::EngineDriver;
use crate::kvcache::chain::ChainRef;
use crate::request::session::{Session, SessionId, TurnId, TurnRecord};
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams};
use crate::util::fxmap::{FxHashMap, FxHashSet};

/// Shard count for the session table. Power of two, sized so a handful
/// of handler threads rarely collide; the shard index is a multiplicative
/// hash of the session id (ids are sequential, so `id % SHARDS` alone
/// would put a burst of new sessions on consecutive shards — fine — but
/// the hash also spreads any id-structured access pattern).
const SHARDS: usize = 16;

fn shard_index(sid: SessionId) -> usize {
    (sid.0.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 56) as usize % SHARDS
}

/// One tenant's lease bookkeeping: which sessions hold leases, how many
/// blocks each pins, and the running total the budget is enforced on.
#[derive(Debug, Default)]
struct TenantLedger {
    total: usize,
    /// session → (acquisition stamp, pinned blocks).
    leases: FxHashMap<SessionId, (f64, usize)>,
}

/// Owns every live session of one server (or one test harness) and
/// drives their turns over an [`EngineDriver`].
#[derive(Debug)]
pub struct SessionManager {
    shards: Vec<Mutex<FxHashMap<SessionId, Session>>>,
    next_id: AtomicU64,
    /// Idle TTL in virtual seconds: a PARKED session (no turn in flight)
    /// idle strictly longer than this expires on the next
    /// [`SessionManager::expire_idle`] sweep — its lease is released and
    /// it leaves the table (a later turn or DELETE sees an unknown
    /// session). None = sessions never age out.
    idle_ttl: Option<f64>,
    /// Hard cap on live sessions: expiry sweeps evict oldest-idle parked
    /// sessions beyond it. None = unbounded.
    max_sessions: Option<usize>,
    /// Per-tenant (per-`cache_salt`) ceiling on leased blocks: when a
    /// tenant's sessions collectively pin more, its OLDEST leases break
    /// first until the tenant fits (counted in
    /// `tenant_lease_breaks_total`). None = no tenant budget.
    tenant_lease_budget: Option<usize>,
    /// cache_salt → ledger. Locked independently of the shards; only
    /// taken with no shard lock held (see module doc).
    tenants: Mutex<FxHashMap<u64, TenantLedger>>,
}

impl Default for SessionManager {
    fn default() -> Self {
        SessionManager {
            shards: (0..SHARDS).map(|_| Mutex::new(FxHashMap::default())).collect(),
            next_id: AtomicU64::new(0),
            idle_ttl: None,
            max_sessions: None,
            tenant_lease_budget: None,
            tenants: Mutex::new(FxHashMap::default()),
        }
    }
}

impl SessionManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// A manager with retention limits (the million-session harness needs
    /// them: unbounded tables are exactly what it exists to rule out) and
    /// an optional per-tenant leased-block budget.
    pub fn with_limits(
        idle_ttl: Option<f64>,
        max_sessions: Option<usize>,
        tenant_lease_budget: Option<usize>,
    ) -> Self {
        SessionManager { idle_ttl, max_sessions, tenant_lease_budget, ..Self::default() }
    }

    pub fn set_idle_ttl(&mut self, ttl: Option<f64>) {
        self.idle_ttl = ttl;
    }

    pub fn set_max_sessions(&mut self, cap: Option<usize>) {
        self.max_sessions = cap;
    }

    pub fn set_tenant_lease_budget(&mut self, budget: Option<usize>) {
        self.tenant_lease_budget = budget;
    }

    fn shard(&self, sid: SessionId) -> &Mutex<FxHashMap<SessionId, Session>> {
        &self.shards[shard_index(sid)]
    }

    /// Open a session under a tenant cache salt (0 = unsalted shared
    /// cache, vLLM semantics).
    pub fn create(&self, cache_salt: u64) -> SessionId {
        self.create_at(cache_salt, 0.0)
    }

    /// [`SessionManager::create`] stamped with the driver's current
    /// virtual clock, so a session that never runs a turn still ages out
    /// of the idle TTL from its creation instant (and not from t=0).
    pub fn create_at(&self, cache_salt: u64, now: f64) -> SessionId {
        let id = SessionId(self.next_id.fetch_add(1, Ordering::Relaxed));
        let mut s = Session::new(id, cache_salt);
        s.last_activity = now;
        self.shard(id).lock().unwrap().insert(id, s);
        id
    }

    /// Expire parked sessions: first any idle strictly longer than the
    /// TTL, then — beyond the session cap — oldest-idle first until the
    /// table fits. Expired sessions release their prefix lease and leave
    /// the table (counted in `sessions_expired_total`); their next turn
    /// or DELETE is an unknown-session error, exactly like an explicit
    /// delete. Sessions with a turn in flight never expire. Returns the
    /// expired ids (ascending idle age, deterministic regardless of the
    /// shard layout: candidates are gathered shard by shard, then sorted
    /// globally by (stamp, id) before victims are chosen).
    pub fn expire_idle<D: EngineDriver>(&self, engine: &mut D) -> Vec<SessionId> {
        let now = engine.clock();
        let mut parked: Vec<(f64, SessionId)> = Vec::new();
        let mut total = 0usize;
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            total += shard.len();
            parked.extend(
                shard
                    .values()
                    .filter(|s| s.in_flight().is_none())
                    .map(|s| (s.last_activity, s.id)),
            );
        }
        // Oldest first; equal stamps break by id so sweeps are
        // deterministic across map iteration orders.
        parked.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut victims: Vec<SessionId> = Vec::new();
        let mut victim_set = FxHashSet::default();
        if let Some(ttl) = self.idle_ttl {
            for &(stamp, id) in &parked {
                if now - stamp > ttl {
                    victims.push(id);
                    victim_set.insert(id);
                }
            }
        }
        if let Some(cap) = self.max_sessions {
            let mut live = total - victims.len();
            for &(_, id) in &parked {
                if live <= cap {
                    break;
                }
                if victim_set.insert(id) {
                    victims.push(id);
                    live -= 1;
                }
            }
        }
        let mut expired = Vec::with_capacity(victims.len());
        for id in victims {
            // Re-check under the shard lock: between the scan and now a
            // concurrent begin_turn may have put the session mid-turn
            // (in-flight sessions never expire).
            let removed = {
                let mut shard = self.shard(id).lock().unwrap();
                match shard.get(&id) {
                    Some(s) if s.in_flight().is_none() => shard.remove(&id),
                    _ => None,
                }
            };
            if let Some(s) = removed {
                engine.release_lease(id.0);
                engine.metrics_mut().sessions_expired += 1;
                self.forget_lease(s.cache_salt, id);
                expired.push(id);
            }
        }
        expired
    }

    /// Snapshot of one session (a clone — the live record sits behind a
    /// shard lock). `None` for unknown ids.
    pub fn get(&self, id: SessionId) -> Option<Session> {
        self.shard(id).lock().unwrap().get(&id).cloned()
    }

    /// The target a forked child was created to serve (`None` for plain
    /// sessions and unknown ids) — the server uses it to default a
    /// turn's target when the body names no adapter. A cheap shard read:
    /// no session clone on the per-turn path.
    pub fn preferred_target(&self, id: SessionId) -> Option<ModelTarget> {
        self.shard(id).lock().unwrap().get(&id).and_then(|s| s.preferred_target)
    }

    /// Test hook: mutate one session in place under its shard lock.
    #[doc(hidden)]
    pub fn with_session_mut<R>(
        &self,
        sid: SessionId,
        f: impl FnOnce(&mut Session) -> R,
    ) -> Option<R> {
        self.shard(sid).lock().unwrap().get_mut(&sid).map(f)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.lock().unwrap().is_empty())
    }

    /// Live session ids, ascending.
    pub fn ids(&self) -> Vec<SessionId> {
        let mut ids: Vec<SessionId> = Vec::new();
        for shard in &self.shards {
            ids.extend(shard.lock().unwrap().keys().copied());
        }
        ids.sort();
        ids
    }

    /// Submit the session's next turn: `delta` extends the conversation,
    /// the engine sees history + delta. Returns the turn and its request
    /// id; the turn stays in flight until [`SessionManager::complete_turn`]
    /// (or [`SessionManager::abort_turn`]).
    pub fn begin_turn<D: EngineDriver>(
        &self,
        engine: &mut D,
        sid: SessionId,
        target: ModelTarget,
        delta: Vec<u32>,
        max_new_tokens: u32,
        append: bool,
    ) -> anyhow::Result<(TurnId, RequestId)> {
        let mut shard = self.shard(sid).lock().unwrap();
        let s = shard
            .get_mut(&sid)
            .ok_or_else(|| anyhow::anyhow!("unknown session {}", sid.0))?;
        let prompt = s.compose_prompt(&delta)?;
        let prompt_len = prompt.len();
        // Hash the turn's chain HERE, through the session's cached chain:
        // a delta turn pays O(delta) hashing instead of re-hashing the
        // whole conversation (the hot-path scaling this layer exists
        // for), and the resulting ChainRef shares the cached history's
        // arena nodes. Unknown adapters fall through with an empty chain
        // so the target replica's own admission emits the canonical
        // error.
        let cache = &engine.config().cache;
        let (bs, ba) = (cache.block_size as usize, cache.base_aligned_hashing);
        let chain = match engine.registry().request_hash_context(
            target.adapter(),
            &prompt,
            ba,
            s.cache_salt,
        ) {
            Some((_, ctx)) => s.turn_chain(&prompt, bs, &ctx),
            None => ChainRef::empty(),
        };
        let id = engine.submit_sticky_prehashed(
            target,
            prompt,
            SamplingParams { max_new_tokens, ..Default::default() },
            true, // continuation priority (paper §4.3)
            s.cache_salt,
            s.last_request,
            Some(sid.0),
            chain,
        )?;
        let turn = s.note_submitted(id, target, delta, append, prompt_len);
        s.last_activity = engine.clock();
        Ok((turn, id))
    }

    /// Apply a finished turn: extend the history, record per-turn metrics
    /// on the driver, and re-acquire the session's prefix lease over the
    /// grown chain (pinned on the replica that just ran the turn).
    pub fn complete_turn<D: EngineDriver>(
        &self,
        engine: &mut D,
        sid: SessionId,
        out: &RequestOutput,
    ) -> anyhow::Result<TurnRecord> {
        let (record, salt, stamp, blocks) = {
            let mut shard = self.shard(sid).lock().unwrap();
            let s = shard
                .get_mut(&sid)
                .ok_or_else(|| anyhow::anyhow!("unknown session {}", sid.0))?;
            let record = s.apply_finished(out)?;
            engine.metrics_mut().observe_turn(out);
            // Re-lease over the cached chain: the turn extended the
            // history, so this is an O(delta) chain extension + an
            // O(delta) lease extension on the holding replica. The
            // ChainRef handle shares the session's interned nodes —
            // no full-chain copy on this per-turn path.
            let bs = engine.config().cache.block_size as usize;
            let chain = s.cached_chain(bs);
            s.leased_blocks = engine.acquire_lease_prehashed(sid.0, &chain, Some(out.id));
            s.last_activity = engine.clock();
            (record, s.cache_salt, s.last_activity, s.leased_blocks)
        };
        // Shard lock dropped: tenant-budget bookkeeping takes the ledger
        // lock and possibly other shards' locks.
        self.note_lease(engine, salt, sid, stamp, blocks);
        Ok(record)
    }

    /// Record a (re)acquired lease in its tenant's ledger and enforce the
    /// budget: while the tenant pins more than its ceiling, break its
    /// OLDEST lease (stamp order, id tie-break) — release it on the
    /// engine, zero the victim session's gauge, count the break.
    fn note_lease<D: EngineDriver>(
        &self,
        engine: &mut D,
        salt: u64,
        sid: SessionId,
        stamp: f64,
        blocks: usize,
    ) {
        let Some(budget) = self.tenant_lease_budget else { return };
        let mut victims: Vec<SessionId> = Vec::new();
        {
            let mut tenants = self.tenants.lock().unwrap();
            let ledger = tenants.entry(salt).or_default();
            let old = if blocks == 0 {
                ledger.leases.remove(&sid)
            } else {
                ledger.leases.insert(sid, (stamp, blocks))
            };
            ledger.total = ledger.total + blocks - old.map_or(0, |(_, b)| b);
            while ledger.total > budget {
                let victim = ledger
                    .leases
                    .iter()
                    .min_by(|a, b| a.1 .0.total_cmp(&b.1 .0).then(a.0.cmp(b.0)))
                    .map(|(id, _)| *id);
                let Some(v) = victim else { break };
                let (_, b) = ledger.leases.remove(&v).expect("picked above");
                ledger.total -= b;
                victims.push(v);
            }
            if ledger.leases.is_empty() {
                tenants.remove(&salt);
            }
        }
        for v in victims {
            engine.release_lease(v.0);
            engine.metrics_mut().tenant_lease_breaks += 1;
            if let Some(s) = self.shard(v).lock().unwrap().get_mut(&v) {
                s.leased_blocks = 0;
            }
        }
    }

    /// Drop a session's ledger entry (lease released or orphaned outside
    /// the budget path). No-op without a tenant budget.
    fn forget_lease(&self, salt: u64, sid: SessionId) {
        if self.tenant_lease_budget.is_none() {
            return;
        }
        let mut tenants = self.tenants.lock().unwrap();
        if let Some(ledger) = tenants.get_mut(&salt) {
            if let Some((_, b)) = ledger.leases.remove(&sid) {
                ledger.total -= b;
            }
            if ledger.leases.is_empty() {
                tenants.remove(&salt);
            }
        }
    }

    /// Drive one turn to completion synchronously (tests and offline
    /// drivers; the HTTP server splits begin/complete around its own
    /// wait). Steps the engine until the turn's output appears, leaving
    /// other traffic's outputs in place.
    ///
    /// Every error exit past submission aborts the in-flight turn: a turn
    /// whose request died without an output (engine stall, requeue
    /// reject) must not leave the session refusing new turns forever
    /// (the stuck-409 bug — the pending turn could only be cleared by a
    /// completion that will never come).
    pub fn run_turn<D: EngineDriver>(
        &self,
        engine: &mut D,
        sid: SessionId,
        target: ModelTarget,
        delta: Vec<u32>,
        max_new_tokens: u32,
        append: bool,
    ) -> anyhow::Result<TurnRecord> {
        let (_turn, rid) = self.begin_turn(engine, sid, target, delta, max_new_tokens, append)?;
        let out = loop {
            if let Some(out) = engine.take_finished_where(|o| o.id == rid).pop() {
                break out;
            }
            if !engine.step() {
                self.abort_turn_if(sid, rid);
                anyhow::bail!("engine stalled waiting on turn {rid:?}");
            }
        };
        self.complete_turn(engine, sid, &out)
    }

    /// Fork a parked session into `k` children
    /// (`POST /v1/sessions/{id}/fork`). Each child shares the parent's
    /// token history and — O(1), arena-interned — its hash-chain handle at
    /// the fork point, then takes its OWN prefix lease over the shared
    /// chain: on the parent's replica that pins the very same blocks
    /// (pure refcount bumps, zero allocations, zero prefill), and the
    /// pool's block refcounts already give last-release-frees semantics —
    /// the shared prefix outlives the parent and every sibling until the
    /// final holder lets go. On a cluster whose parent replica has died,
    /// the child's pin falls back to [`EngineDriver::migrate_lease`]
    /// (cost model permitting) and to plain recompute otherwise.
    ///
    /// `targets[i]` assigns child `i` its preferred target (what turns
    /// without an explicit adapter run against) — the fan-out-K-adapters-
    /// over-one-conversation shape from the paper; missing entries
    /// inherit the parent's. Refuses mid-turn (the fork point would be
    /// ambiguous while the history is still growing).
    pub fn fork<D: EngineDriver>(
        &self,
        engine: &mut D,
        parent: SessionId,
        k: usize,
        targets: &[Option<ModelTarget>],
    ) -> anyhow::Result<Vec<SessionId>> {
        anyhow::ensure!(k >= 1, "fork count must be at least 1");
        let now = engine.clock();
        let snapshot = {
            let shard = self.shard(parent).lock().unwrap();
            let s = shard
                .get(&parent)
                .ok_or_else(|| anyhow::anyhow!("unknown session {}", parent.0))?;
            if let Some(rid) = s.in_flight() {
                anyhow::bail!("session {}: turn {rid:?} is still in flight", parent.0);
            }
            s.clone()
        };
        let bs = engine.config().cache.block_size as usize;
        let mut children = Vec::with_capacity(k);
        for i in 0..k {
            let id = SessionId(self.next_id.fetch_add(1, Ordering::Relaxed));
            let target = targets.get(i).copied().flatten().or(snapshot.preferred_target);
            let mut child = Session::forked(id, &snapshot, target, now);
            let chain = child.cached_chain(bs);
            let mut pinned = engine.acquire_lease_prehashed(id.0, &chain, child.last_request);
            if pinned == 0 && !chain.is_empty() {
                pinned = engine.migrate_lease(id.0, &chain, child.last_request);
            }
            child.leased_blocks = pinned;
            let (salt, stamp) = (child.cache_salt, child.last_activity);
            self.shard(id).lock().unwrap().insert(id, child);
            if pinned > 0 {
                self.note_lease(engine, salt, id, stamp, pinned);
            }
            children.push(id);
        }
        engine.note_session_forks(k as u64);
        Ok(children)
    }

    /// Repair sessions after a replica failure
    /// ([`crate::cluster::Cluster::fail_replica`]): sessions whose prefix
    /// lease died with the replica forget it (the next turn transparently
    /// re-prefills — observable as recomputed tokens, never as an error),
    /// sessions stuck to the dead replica clear their stickiness peer (the
    /// next turn re-sticks through the routing policy, wherever its chain
    /// scores best — cold if nothing survives; counted into the fleet's
    /// `resticks_total` through the driver), and sessions whose in-flight
    /// turn was REJECTED at requeue abort it (no output will ever come —
    /// without the abort every later turn would 409, the stuck-turn bug).
    ///
    /// With `cache.prefix_migration` on, each orphaned session's chain is
    /// then offered to [`EngineDriver::migrate_lease`]: the session layer
    /// still holds the conversation tokens (and the leased KV is host-
    /// recoverable, DESIGN.md §18), so the fleet may rebuild the pinned
    /// prefix on a survivor at a modeled transfer cost instead of letting
    /// the next turn re-prefill from token zero. A declined or failed
    /// migration leaves the recompute behavior above exactly as it was.
    /// Returns (leases dropped, stickiness cleared, turns aborted).
    pub fn repair_after_failover<D: EngineDriver>(
        &self,
        engine: &mut D,
        report: &crate::cluster::FailoverReport,
    ) -> (usize, usize, usize) {
        // Hash the report's id lists once: this loop runs over every live
        // session while its shard lock is held, so per-session linear
        // scans of a loaded victim's lists would go quadratic exactly
        // when latency matters most.
        let orphaned: FxHashSet<u64> = report.orphaned_leases.iter().copied().collect();
        let rejected: FxHashSet<RequestId> = report.rejected.iter().copied().collect();
        let relocated: FxHashSet<RequestId> = report.relocated.iter().copied().collect();
        // The set-based form of `FailoverReport::strands`.
        let stranded = |rid: RequestId| {
            (rid.0 % report.num_replicas as u64) as usize == report.replica
                && !relocated.contains(&rid)
        };
        let (mut leases, mut unstuck, mut aborted) = (0, 0, 0);
        let bs = engine.config().cache.block_size as usize;
        let mut dropped: Vec<(u64, SessionId)> = Vec::new();
        // Orphaned chains worth offering to migration: (salt, id, chain,
        // stickiness peer at repair time — the requeued in-flight turn's
        // survivor if any, else the stale last request the policy pick
        // falls back from). Gathered under the shard locks, migrated
        // after they drop (the driver call may take its own locks).
        let mut migrate: Vec<(u64, SessionId, ChainRef, Option<RequestId>)> = Vec::new();
        for shard in &self.shards {
            let mut shard = shard.lock().unwrap();
            for s in shard.values_mut() {
                if s.leased_blocks > 0 && orphaned.contains(&s.id.0) {
                    s.leased_blocks = 0;
                    dropped.push((s.cache_salt, s.id));
                    leases += 1;
                    let peer = s.in_flight().or(s.last_request);
                    migrate.push((s.cache_salt, s.id, s.cached_chain(bs), peer));
                }
                // Clear stickiness only for PARKED sessions (no turn in
                // flight). A session mid-turn is re-homed by that turn's
                // own completion — requeued turns finish on a survivor
                // and overwrite `last_request`, and a turn that finished
                // on the victim (or was rejected and aborted below)
                // leaves a stale peer that `submit_sticky`'s health check
                // re-sticks — and counts — exactly once. Clearing here
                // too would count the same migration twice.
                if s.in_flight().is_none() {
                    if let Some(rid) = s.last_request {
                        if stranded(rid) {
                            s.last_request = None;
                            unstuck += 1;
                        }
                    }
                }
                if let Some(rid) = s.in_flight() {
                    if rejected.contains(&rid) {
                        s.abort_pending();
                        aborted += 1;
                    }
                }
            }
        }
        for (salt, sid) in dropped {
            self.forget_lease(salt, sid);
        }
        // Offer each orphaned chain to the fleet's migration path. The
        // driver decides (flag, cost model, destination health) and a 0
        // return changes nothing — the session stays unleased and the
        // next turn recomputes, exactly the pre-migration behavior.
        for (salt, sid, chain, peer) in migrate {
            let pinned = engine.migrate_lease(sid.0, &chain, peer);
            if pinned > 0 {
                let stamp = engine.clock();
                if let Some(s) = self.shard(sid).lock().unwrap().get_mut(&sid) {
                    s.leased_blocks = pinned;
                }
                self.note_lease(engine, salt, sid, stamp, pinned);
            }
        }
        engine.note_resticks(unstuck as u64);
        (leases, unstuck, aborted)
    }

    /// Abandon the in-flight turn (client went away). The engine keeps
    /// running the request; the returned id lets the caller discard its
    /// eventual output. The session history stays at the last completed
    /// turn.
    pub fn abort_turn(&self, sid: SessionId) -> Option<RequestId> {
        self.shard(sid).lock().unwrap().get_mut(&sid).and_then(Session::abort_pending)
    }

    /// Abort the in-flight turn only if it is `rid` — the guard every
    /// *asynchronous* error path needs: by the time a waiter times out or
    /// its socket dies, failover repair may already have aborted its turn
    /// and the session may be running a NEWER turn, which an
    /// unconditional abort would destroy. True if the abort happened.
    pub fn abort_turn_if(&self, sid: SessionId, rid: RequestId) -> bool {
        match self.shard(sid).lock().unwrap().get_mut(&sid) {
            Some(s) if s.in_flight() == Some(rid) => {
                s.abort_pending();
                true
            }
            _ => false,
        }
    }

    /// Close a session: release its prefix lease and drop its state.
    /// Refuses while a turn is in flight (abort it first).
    pub fn delete<D: EngineDriver>(
        &self,
        engine: &mut D,
        sid: SessionId,
    ) -> anyhow::Result<Session> {
        let removed = {
            let mut shard = self.shard(sid).lock().unwrap();
            let s = shard
                .get(&sid)
                .ok_or_else(|| anyhow::anyhow!("unknown session {}", sid.0))?;
            if let Some(rid) = s.in_flight() {
                anyhow::bail!("session {}: turn {rid:?} is still in flight", sid.0);
            }
            shard.remove(&sid).expect("checked above")
        };
        engine.release_lease(sid.0);
        self.forget_lease(removed.cache_salt, sid);
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::{AdapterId, AdapterRegistry};
    use crate::config::{presets, EngineConfig};
    use crate::engine::Engine;
    use crate::metrics::Metrics;
    use crate::pipeline::workload;
    use crate::simulator::SimExecutor;

    fn engine() -> Engine<SimExecutor> {
        let cfg = presets::granite_8b();
        let reg = workload::build_registry(2, cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&cfg);
        Engine::with_registry(cfg, reg, exec)
    }

    /// A driver whose requests die without ever producing an output:
    /// submission succeeds (ids 0, 2, 4, ... — "replica 0 of 2"), stepping
    /// stalls forever. Models the failure classes behind the stuck-409
    /// bug: engine reject at requeue, abort, a request lost by a dead
    /// replica.
    struct DeadEndDriver {
        cfg: EngineConfig,
        reg: AdapterRegistry,
        metrics: Metrics,
        next: u64,
    }

    impl DeadEndDriver {
        fn new() -> Self {
            DeadEndDriver {
                cfg: presets::tiny(),
                reg: AdapterRegistry::tiny_default(1, 512, 4),
                metrics: Metrics::new(),
                next: 0,
            }
        }
    }

    impl EngineDriver for DeadEndDriver {
        fn submit_salted(
            &mut self,
            _target: ModelTarget,
            _prompt: Vec<u32>,
            _params: crate::request::SamplingParams,
            _priority: bool,
            _cache_salt: u64,
        ) -> anyhow::Result<RequestId> {
            let id = RequestId(self.next);
            self.next += 2;
            Ok(id)
        }

        fn step(&mut self) -> bool {
            false
        }

        fn clock(&self) -> f64 {
            0.0
        }

        fn advance_clock_to(&mut self, _t: f64) {}

        fn has_work(&self) -> bool {
            true
        }

        fn num_waiting(&self) -> usize {
            1
        }

        fn num_running(&self) -> usize {
            0
        }

        fn take_finished(&mut self) -> Vec<RequestOutput> {
            Vec::new()
        }

        fn finished_pending(&self) -> usize {
            0
        }

        fn take_finished_where<F: FnMut(&RequestOutput) -> bool>(
            &mut self,
            _pred: F,
        ) -> Vec<RequestOutput> {
            Vec::new()
        }

        fn metrics(&self) -> &Metrics {
            &self.metrics
        }

        fn metrics_mut(&mut self) -> &mut Metrics {
            &mut self.metrics
        }

        fn config(&self) -> &EngineConfig {
            &self.cfg
        }

        fn registry(&self) -> &AdapterRegistry {
            &self.reg
        }
    }

    #[test]
    fn turn_dying_without_output_aborts_instead_of_wedging() {
        // The stuck-409 regression (ISSUE 5 satellite): a turn whose
        // request dies without a RequestOutput must not leave the session
        // rejecting every later turn as `turn_in_flight`.
        let mut d = DeadEndDriver::new();
        let mgr = SessionManager::new();
        let sid = mgr.create(0);
        // While a turn is live the session 409s...
        let (_t, rid) = mgr
            .begin_turn(&mut d, sid, ModelTarget::Base, vec![1, 2, 3], 4, true)
            .unwrap();
        let err = mgr
            .begin_turn(&mut d, sid, ModelTarget::Base, vec![9], 4, true)
            .unwrap_err();
        assert!(err.to_string().contains("in flight"), "{err}");
        assert_eq!(mgr.get(sid).unwrap().in_flight(), Some(rid));
        mgr.abort_turn(sid);
        // ...and run_turn's own error exit (the request stalls and never
        // produces output) aborts the pending turn instead of wedging.
        let err = mgr
            .run_turn(&mut d, sid, ModelTarget::Base, vec![4, 5], 4, true)
            .unwrap_err();
        assert!(err.to_string().contains("stalled"), "{err}");
        assert!(
            mgr.get(sid).unwrap().in_flight().is_none(),
            "error exit must abort the dead turn"
        );
        // The session accepts a new turn immediately — no 409, no
        // history damage.
        assert!(mgr
            .begin_turn(&mut d, sid, ModelTarget::Base, vec![6], 4, true)
            .is_ok());
        assert_eq!(mgr.get(sid).unwrap().history_len(), 0);
    }

    #[test]
    fn failover_repair_aborts_rejected_turns_and_clears_dead_state() {
        let mut d = DeadEndDriver::new();
        let mgr = SessionManager::new();
        let sid = mgr.create(0);
        let (_t, rid) = mgr
            .begin_turn(&mut d, sid, ModelTarget::Base, vec![1, 2], 4, true)
            .unwrap();
        // Fake a session that already completed a turn on "replica 0".
        mgr.with_session_mut(sid, |s| {
            s.last_request = Some(RequestId(100)); // 100 % 2 == 0: stranded
            s.leased_blocks = 3;
        })
        .unwrap();
        let report = crate::cluster::FailoverReport {
            replica: 0,
            num_replicas: 2,
            requeued: 0,
            orphaned_leases: vec![sid.0],
            rejected: vec![rid],
            relocated: Vec::new(),
        };
        let (leases, unstuck, aborted) = mgr.repair_after_failover(&mut d, &report);
        // The mid-turn session does NOT count an unstuck: its stale peer
        // is re-stuck (and counted) lazily by submit_sticky's health
        // check — clearing here too would double-count the migration.
        assert_eq!((leases, unstuck, aborted), (1, 0, 1));
        let s = mgr.get(sid).unwrap();
        assert_eq!(s.leased_blocks, 0, "orphaned lease forgotten");
        assert_eq!(
            s.last_request,
            Some(RequestId(100)),
            "mid-turn stickiness left for the lazy health-check re-stick"
        );
        assert!(s.in_flight().is_none(), "rejected turn aborted — no 409 wedge");
        // A PARKED session (no turn in flight) does clear eagerly — the
        // first session, now aborted, is parked too, so a second repair
        // clears both.
        let parked = mgr.create(0);
        mgr.with_session_mut(parked, |s| s.last_request = Some(RequestId(100)))
            .unwrap();
        let (_, unstuck, _) = mgr.repair_after_failover(&mut d, &report);
        assert_eq!(unstuck, 2, "parked sessions' stickiness cleared");
        assert!(mgr.get(parked).unwrap().last_request.is_none());
        assert!(mgr.get(sid).unwrap().last_request.is_none());
        // A relocated id is NOT stranded: stickiness to a survivor holds.
        let report2 = crate::cluster::FailoverReport {
            replica: 0,
            num_replicas: 2,
            requeued: 1,
            orphaned_leases: Vec::new(),
            rejected: Vec::new(),
            relocated: vec![RequestId(42)],
        };
        assert!(!report2.strands(RequestId(42)));
        assert!(report2.strands(RequestId(44)));
        assert!(!report2.strands(RequestId(43)), "other replica's id untouched");
    }

    #[test]
    fn delta_turns_reuse_prior_turn_kv() {
        let mut e = engine();
        let mgr = SessionManager::new();
        let sid = mgr.create(0);
        let t1 = mgr
            .run_turn(&mut e, sid, ModelTarget::Base, (0..256).collect(), 32, true)
            .unwrap();
        assert_eq!(t1.cached_tokens, 0, "cold first turn");
        assert_eq!(mgr.get(sid).unwrap().history_len(), 288);
        assert!(mgr.get(sid).unwrap().leased_blocks > 0, "chain leased");
        // Turn 2 submits only a 16-token delta; the engine reconstructs
        // the 288-token chain and hits the committed prefix.
        let t2 = mgr
            .run_turn(&mut e, sid, ModelTarget::Base, (900..916).collect(), 16, true)
            .unwrap();
        assert_eq!(t2.prompt_len, 304);
        assert_eq!(t2.delta_len, 16);
        assert!(t2.cached_tokens >= 272, "follow-up hit: {}", t2.cached_tokens);
        assert!(t2.ttft_s < t1.ttft_s, "warm turn strictly faster");
        // aLoRA intrinsic side branch over the conversation (append=false).
        let vocab = e.cfg.model.vocab_size;
        let t3 = mgr
            .run_turn(
                &mut e,
                sid,
                ModelTarget::Adapter(AdapterId(0)),
                workload::invocation_for(vocab, 0),
                8,
                false,
            )
            .unwrap();
        assert!(t3.cached_tokens >= 288, "cross-model hit over the session chain");
        let hist_after = mgr.get(sid).unwrap().history_len();
        assert_eq!(hist_after, 304 + 16, "branch did not extend the chain");
        // Per-turn series landed on the driver's metrics.
        assert_eq!(e.metrics.turn.count(), 3);
        // Delete releases the lease; nothing leaks.
        mgr.delete(&mut e, sid).unwrap();
        assert_eq!(e.leased_blocks(), 0);
        e.check_invariants().unwrap();
    }

    #[test]
    fn tenant_salts_isolate_sessions_sharing_a_prompt() {
        let mut e = engine();
        let mgr = SessionManager::new();
        let a = mgr.create(111);
        let b = mgr.create(222);
        let c = mgr.create(111); // same tenant as `a`
        let prompt: Vec<u32> = (0..256).collect();
        let ta = mgr
            .run_turn(&mut e, a, ModelTarget::Base, prompt.clone(), 8, true)
            .unwrap();
        assert_eq!(ta.cached_tokens, 0);
        // Different tenant, identical prompt: MUST NOT share blocks.
        let tb = mgr
            .run_turn(&mut e, b, ModelTarget::Base, prompt.clone(), 8, true)
            .unwrap();
        assert_eq!(tb.cached_tokens, 0, "cross-tenant hit");
        // Same tenant: sharing is allowed (the salt partitions tenants,
        // not sessions).
        let tc = mgr
            .run_turn(&mut e, c, ModelTarget::Base, prompt, 8, true)
            .unwrap();
        assert!(tc.cached_tokens > 0, "same-tenant session shares its prefix");
        for sid in [a, b, c] {
            mgr.delete(&mut e, sid).unwrap();
        }
        e.check_invariants().unwrap();
    }

    #[test]
    fn tenant_lease_budget_breaks_oldest_and_isolates_tenants() {
        // Per-tenant leased-block ceiling: tenant A runs two sessions
        // whose leases together exceed the budget — A's OLDEST lease
        // breaks; tenant B (its own salt, its own budget) keeps its lease
        // untouched.
        let mut e = engine();
        let mgr = SessionManager::with_limits(None, None, Some(24));
        let a1 = mgr.create(111);
        let a2 = mgr.create(111);
        let b = mgr.create(222);
        // Tenant B leases ~17 blocks (264 tokens / bs 16): within budget.
        mgr.run_turn(&mut e, b, ModelTarget::Base, (500..756).collect(), 8, true)
            .unwrap();
        let b_leased = mgr.get(b).unwrap().leased_blocks;
        assert!(b_leased > 0 && b_leased <= 24, "b leased {b_leased}");
        // Tenant A's first session: also within budget on its own.
        mgr.run_turn(&mut e, a1, ModelTarget::Base, (0..256).collect(), 8, true)
            .unwrap();
        let a1_leased = mgr.get(a1).unwrap().leased_blocks;
        assert!(a1_leased > 0 && a1_leased <= 24, "a1 leased {a1_leased}");
        assert_eq!(e.metrics.tenant_lease_breaks, 0);
        // A's second session pushes the tenant past 24 blocks: the OLDEST
        // lease (a1's) breaks; the fresh one survives.
        mgr.run_turn(&mut e, a2, ModelTarget::Base, (2000..2256).collect(), 8, true)
            .unwrap();
        assert_eq!(
            mgr.get(a1).unwrap().leased_blocks,
            0,
            "tenant over budget: oldest lease broken"
        );
        assert!(mgr.get(a2).unwrap().leased_blocks > 0, "newest lease kept");
        assert_eq!(e.metrics.tenant_lease_breaks, 1);
        // Tenant isolation: B's lease is untouched by A's overage.
        assert_eq!(mgr.get(b).unwrap().leased_blocks, b_leased, "tenant B isolated");
        // The engine agrees: only a2's and b's chains stay pinned.
        assert_eq!(
            e.leased_blocks(),
            mgr.get(a2).unwrap().leased_blocks + b_leased
        );
        for sid in [a1, a2, b] {
            mgr.delete(&mut e, sid).unwrap();
        }
        assert_eq!(e.leased_blocks(), 0);
        e.check_invariants().unwrap();
    }

    #[test]
    fn sequential_turn_discipline_and_delete_guard() {
        let mut e = engine();
        let mgr = SessionManager::new();
        let sid = mgr.create(0);
        let (_t, rid) = mgr
            .begin_turn(&mut e, sid, ModelTarget::Base, vec![1, 2, 3, 4], 4, true)
            .unwrap();
        // Second turn while one is in flight: refused.
        let err = mgr
            .begin_turn(&mut e, sid, ModelTarget::Base, vec![5], 4, true)
            .unwrap_err();
        assert!(err.to_string().contains("in flight"), "{err}");
        // Delete while in flight: refused.
        assert!(mgr.delete(&mut e, sid).is_err());
        // Completing clears the way.
        let out = loop {
            if let Some(o) = e.take_finished_where(|o| o.id == rid).pop() {
                break o;
            }
            assert!(e.step());
        };
        mgr.complete_turn(&mut e, sid, &out).unwrap();
        assert_eq!(mgr.get(sid).unwrap().num_turns(), 1);
        mgr.delete(&mut e, sid).unwrap();
        assert!(mgr.get(sid).is_none());
        assert!(mgr.delete(&mut e, sid).is_err(), "double delete");
    }

    #[test]
    fn idle_sessions_expire_and_release_leases() {
        let mut e = engine();
        let mgr = SessionManager::with_limits(Some(100.0), None, None);
        let a = mgr.create(0);
        let b = mgr.create(0);
        mgr.run_turn(&mut e, a, ModelTarget::Base, (0..64).collect(), 8, true)
            .unwrap();
        mgr.run_turn(&mut e, b, ModelTarget::Base, (100..164).collect(), 8, true)
            .unwrap();
        assert!(e.leased_blocks() > 0);
        // Nothing is stale yet: the sweep is a no-op.
        assert!(mgr.expire_idle(&mut e).is_empty());
        assert_eq!(mgr.len(), 2);
        // Let both go stale, then refresh only `b` with a fresh turn.
        let t = e.clock();
        e.advance_clock_to(t + 250.0);
        mgr.run_turn(&mut e, b, ModelTarget::Base, (200..208).collect(), 8, true)
            .unwrap();
        let before = e.leased_blocks();
        let expired = mgr.expire_idle(&mut e);
        assert_eq!(expired, vec![a], "only the stale parked session expires");
        assert_eq!(e.metrics.sessions_expired, 1);
        assert!(e.leased_blocks() < before, "expiry released the lease");
        // The expired session is GONE — its next DELETE (or turn) is an
        // unknown-session error, same as an explicit delete.
        assert!(mgr.delete(&mut e, a).is_err());
        mgr.delete(&mut e, b).unwrap();
        assert_eq!(e.leased_blocks(), 0);
        e.check_invariants().unwrap();
    }

    #[test]
    fn session_cap_evicts_oldest_idle_first() {
        let mut d = DeadEndDriver::new();
        let mgr = SessionManager::with_limits(None, Some(2), None);
        let a = mgr.create_at(0, 10.0);
        let b = mgr.create_at(0, 20.0);
        let c = mgr.create_at(0, 5.0);
        let expired = mgr.expire_idle(&mut d);
        assert_eq!(expired, vec![c], "oldest-idle evicted down to the cap");
        assert_eq!(mgr.len(), 2);
        assert!(mgr.get(a).is_some() && mgr.get(b).is_some());
        assert_eq!(d.metrics.sessions_expired, 1);
        // Under the cap again: no-op.
        assert!(mgr.expire_idle(&mut d).is_empty());
    }

    #[test]
    fn in_flight_sessions_never_expire() {
        let mut d = DeadEndDriver::new();
        let mgr = SessionManager::with_limits(Some(10.0), None, None);
        let busy = mgr.create(0);
        let parked = mgr.create(0);
        let (_t, rid) = mgr
            .begin_turn(&mut d, busy, ModelTarget::Base, vec![1, 2], 4, true)
            .unwrap();
        for sid in [busy, parked] {
            mgr.with_session_mut(sid, |s| s.last_activity = -100.0).unwrap();
        }
        let expired = mgr.expire_idle(&mut d);
        assert_eq!(expired, vec![parked], "mid-turn session is immune");
        assert!(mgr.get(busy).is_some());
        // Once aborted the session is parked — and collectable.
        assert_eq!(mgr.abort_turn(busy), Some(rid));
        mgr.with_session_mut(busy, |s| s.last_activity = -100.0).unwrap();
        assert_eq!(mgr.expire_idle(&mut d), vec![busy]);
        assert!(mgr.is_empty());
        assert_eq!(d.metrics.sessions_expired, 2);
    }

    #[test]
    fn aborted_turn_leaves_history_and_engine_consistent() {
        let mut e = engine();
        let mgr = SessionManager::new();
        let sid = mgr.create(0);
        mgr.run_turn(&mut e, sid, ModelTarget::Base, (0..64).collect(), 8, true)
            .unwrap();
        let hist = mgr.get(sid).unwrap().history_len();
        let (_t, rid) = mgr
            .begin_turn(&mut e, sid, ModelTarget::Base, vec![7; 16], 8, true)
            .unwrap();
        assert_eq!(mgr.abort_turn(sid), Some(rid));
        assert_eq!(mgr.get(sid).unwrap().history_len(), hist, "history unchanged");
        // The orphaned request still runs to completion; its output is
        // simply unclaimed by the session.
        e.run_until_idle();
        let leftover = e.take_finished();
        assert!(leftover.iter().any(|o| o.id == rid));
        // A fresh turn proceeds normally after the abort.
        let t = mgr
            .run_turn(&mut e, sid, ModelTarget::Base, vec![8; 16], 8, true)
            .unwrap();
        assert!(t.cached_tokens > 0);
        mgr.delete(&mut e, sid).unwrap();
        e.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_parent_prefix_with_zero_new_blocks() {
        // ISSUE-8 acceptance (b): a K=4 fork on one replica pins the
        // shared chain four more times without allocating a single new
        // block — the children reference the parent's KV, not copies.
        let mut e = engine();
        let mgr = SessionManager::new();
        let parent = mgr.create(0);
        mgr.run_turn(&mut e, parent, ModelTarget::Base, (0..256).collect(), 32, true)
            .unwrap();
        let parent_leased = mgr.get(parent).unwrap().leased_blocks;
        assert!(parent_leased > 0);
        let allocated_before = e.metrics.blocks_allocated;
        let kids = mgr.fork(&mut e, parent, 4, &[]).unwrap();
        assert_eq!(kids.len(), 4);
        assert_eq!(
            e.metrics.blocks_allocated, allocated_before,
            "fork must not prefill or copy a single block"
        );
        for k in &kids {
            let c = mgr.get(*k).unwrap();
            assert_eq!(c.leased_blocks, parent_leased, "child pins the shared chain");
            assert_eq!(c.history_len(), 288, "history shared at the fork point");
            assert_eq!(c.num_turns(), 0);
        }
        // Each child's first turn rides the shared prefix warm, and the
        // branches diverge without touching the parent.
        for (i, k) in kids.iter().enumerate() {
            let t = mgr
                .run_turn(&mut e, *k, ModelTarget::Base, vec![900 + i as u32; 16], 8, true)
                .unwrap();
            assert!(t.cached_tokens >= 256, "child {i} warm: {}", t.cached_tokens);
        }
        assert_eq!(mgr.get(parent).unwrap().history_len(), 288, "parent untouched");
        // Releases in arbitrary order: the shared blocks stay pinned until
        // the LAST holder lets go, then everything drains to zero.
        mgr.delete(&mut e, kids[2]).unwrap();
        mgr.delete(&mut e, parent).unwrap();
        assert!(e.leased_blocks() > 0, "surviving children still pin the chain");
        for k in [kids[0], kids[3], kids[1]] {
            mgr.delete(&mut e, k).unwrap();
        }
        assert_eq!(e.leased_blocks(), 0, "last release freed the shared prefix");
        e.check_invariants().unwrap();
    }

    #[test]
    fn fork_guards_unknown_mid_turn_and_zero_count() {
        let mut d = DeadEndDriver::new();
        let mgr = SessionManager::new();
        let sid = mgr.create(7);
        assert!(mgr.fork(&mut d, SessionId(999), 2, &[]).is_err(), "unknown parent");
        assert!(mgr.fork(&mut d, sid, 0, &[]).is_err(), "zero children");
        mgr.begin_turn(&mut d, sid, ModelTarget::Base, vec![1, 2], 4, true).unwrap();
        let err = mgr.fork(&mut d, sid, 2, &[]).unwrap_err();
        assert!(err.to_string().contains("in flight"), "{err}");
        mgr.abort_turn(sid);
        // Parked again: the fork works even on a driver that can't lease
        // (leased_blocks stays 0; the chain simply recomputes on demand),
        // and per-child targets land on the children in order.
        let kids = mgr
            .fork(
                &mut d,
                sid,
                2,
                &[Some(ModelTarget::Adapter(AdapterId(0))), None],
            )
            .unwrap();
        assert_eq!(kids.len(), 2);
        assert_eq!(
            mgr.get(kids[0]).unwrap().preferred_target,
            Some(ModelTarget::Adapter(AdapterId(0)))
        );
        assert_eq!(mgr.get(kids[1]).unwrap().preferred_target, None);
        assert_eq!(mgr.get(kids[0]).unwrap().cache_salt, 7, "tenant salt inherited");
        assert_eq!(mgr.len(), 3);
    }

    #[test]
    fn sharded_table_spreads_sessions_and_keeps_ids_ascending() {
        let mgr = SessionManager::new();
        let ids: Vec<SessionId> = (0..64).map(|_| mgr.create(0)).collect();
        assert_eq!(mgr.len(), 64);
        assert_eq!(mgr.ids(), ids, "ids() is ascending and complete");
        // Sequential ids must not pile onto one shard.
        let mut per_shard = [0usize; SHARDS];
        for id in &ids {
            per_shard[shard_index(*id)] += 1;
        }
        let populated = per_shard.iter().filter(|&&n| n > 0).count();
        assert!(populated > SHARDS / 2, "shard spread: {per_shard:?}");
    }
}
