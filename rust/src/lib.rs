//! # alora-serve
//!
//! Multi-adapter LLM serving with **cross-model KV-cache reuse via
//! Activated LoRA (aLoRA)** — a reproduction of Li et al. (CS.DC 2025)
//! as a three-layer rust + JAX/Pallas stack:
//!
//! - **L3 (this crate)**: the serving layer — continuous-batching
//!   scheduler with chunked prefill, PagedAttention-style block manager
//!   with *base-aligned prefix caching* (the paper's contribution),
//!   adapter registry, activation-aware mask metadata, metrics, the
//!   stage-graph [`coordinator`] orchestrating multi-adapter DAG
//!   pipelines over any [`engine::EngineDriver`] — a single engine or a
//!   [`cluster`] of replicas behind a prefix-affinity router — the H100
//!   discrete-event simulator, and a PJRT runtime that executes the
//!   AOT-compiled model.
//! - **L2**: `python/compile/model.py` — the JAX transformer `step`
//!   function, lowered once to `artifacts/tiny_step.hlo.txt`.
//! - **L1**: `python/compile/kernels/` — Pallas kernels for the fused
//!   activation-aware QKV projection and blocked attention.
//!
//! Python never runs at serving time; the rust binary is self-contained
//! once `make artifacts` has produced the HLO text.
//!
//! ## Quick tour
//!
//! ```no_run
//! use alora_serve::config::presets;
//! use alora_serve::engine::Engine;
//! use alora_serve::simulator::SimExecutor;
//!
//! let cfg = presets::granite_8b();
//! let exec = SimExecutor::new(&cfg);
//! let mut engine = Engine::new(cfg, exec);
//! // submit requests, then drive: engine.step() until done
//! ```
//!
//! See `examples/` for runnable pipelines and `rust/benches/` for the
//! paper's table/figure reproductions.

pub mod adapter;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod figures;
pub mod kvcache;
pub mod memory;
pub mod metrics;
pub mod pipeline;
pub mod request;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod session;
pub mod simulator;
pub mod util;
