//! Table-1 testbed presets + the `tiny` real-model config.
//!
//! | Model            | params | GPUs    | max KV tokens |
//! |------------------|--------|---------|---------------|
//! | Granite 3.2 8B   | 8B     | 1×H100  | 351,104       |
//! | Llama 3.3 70B    | 70B    | 4×H100  | 407,984       |
//! | Mistral Large 2  | 123B   | 8×H100  | 912,688       |
//!
//! Architecture dims for the large models follow their public model cards;
//! they only feed the cost model (FLOPs + bytes), not numerics. The `tiny`
//! preset mirrors python/compile/configs.py and must stay in sync with the
//! AOT manifest (enforced by rust/tests/real_runtime.rs).

use super::{CacheConfig, EngineConfig, GpuConfig, ModelConfig, SchedulerConfig};

pub const PRESET_NAMES: &[&str] = &["tiny", "granite-8b", "llama-70b", "mistral-large-2"];

pub fn by_name(name: &str) -> Option<EngineConfig> {
    match name {
        "tiny" => Some(tiny()),
        "granite-8b" => Some(granite_8b()),
        "llama-70b" => Some(llama_70b()),
        "mistral-large-2" => Some(mistral_large_2()),
        _ => None,
    }
}

/// The real-PJRT-path model (python/compile/configs.py::TINY).
pub fn tiny() -> EngineConfig {
    EngineConfig {
        model: ModelConfig {
            name: "tiny".into(),
            n_params: 0.91e6,
            n_layers: 4,
            d_model: 128,
            n_heads: 4,
            n_kv_heads: 4,
            vocab_size: 512,
            dtype_bytes: 4, // f32 on CPU
            lora_rank: 8,
            alora_rank: 32,
        },
        gpu: GpuConfig::h100(1), // unused on the real path; kept for uniformity
        cache: CacheConfig {
            block_size: 16,
            // 128 blocks — enough for a handful of concurrent tiny requests
            // while still being exhaustible in eviction tests.
            max_kv_tokens: 2048,
            enable_prefix_caching: true,
            base_aligned_hashing: true,
            adapter_paging: false,
            prefix_migration: false,
            adapter_load_bw: 0.0,
            adapter_load_setup: 0.0,
            host_adapter_blocks: 0,
            adapter_prefetch: false,
        },
        scheduler: SchedulerConfig {
            max_batch_tokens: 256,
            max_num_seqs: 8,
            max_seq_len: 160,
            admission_watermark: 1.0,
        },
        seed: 0,
    }
}

/// Granite 3.2 8B on 1×H100 (Table 1 col 1).
pub fn granite_8b() -> EngineConfig {
    EngineConfig {
        model: ModelConfig {
            name: "granite-8b".into(),
            n_params: 8.17e9,
            n_layers: 40,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            vocab_size: 49_155,
            dtype_bytes: 2,
            lora_rank: 8,
            alora_rank: 32,
        },
        gpu: GpuConfig::h100(1),
        cache: CacheConfig {
            block_size: 16,
            max_kv_tokens: 351_104,
            enable_prefix_caching: true,
            base_aligned_hashing: true,
            adapter_paging: false,
            prefix_migration: false,
            adapter_load_bw: 0.0,
            adapter_load_setup: 0.0,
            host_adapter_blocks: 0,
            adapter_prefetch: false,
        },
        scheduler: SchedulerConfig {
            max_batch_tokens: 8192,
            max_num_seqs: 256,
            max_seq_len: 131_072,
            admission_watermark: 1.0,
        },
        seed: 0,
    }
}

/// Llama 3.3 70B on 4×H100 TP (Table 1 col 2).
pub fn llama_70b() -> EngineConfig {
    EngineConfig {
        model: ModelConfig {
            name: "llama-70b".into(),
            n_params: 70.6e9,
            n_layers: 80,
            d_model: 8192,
            n_heads: 64,
            n_kv_heads: 8,
            vocab_size: 128_256,
            dtype_bytes: 2,
            lora_rank: 8,
            alora_rank: 32,
        },
        gpu: GpuConfig::h100(4),
        cache: CacheConfig {
            block_size: 16,
            max_kv_tokens: 407_984,
            enable_prefix_caching: true,
            base_aligned_hashing: true,
            adapter_paging: false,
            prefix_migration: false,
            adapter_load_bw: 0.0,
            adapter_load_setup: 0.0,
            host_adapter_blocks: 0,
            adapter_prefetch: false,
        },
        scheduler: SchedulerConfig {
            max_batch_tokens: 8192,
            max_num_seqs: 256,
            max_seq_len: 131_072,
            admission_watermark: 1.0,
        },
        seed: 0,
    }
}

/// Mistral Large 2 (123B) on 8×H100 TP (Table 1 col 3).
pub fn mistral_large_2() -> EngineConfig {
    EngineConfig {
        model: ModelConfig {
            name: "mistral-large-2".into(),
            n_params: 123e9,
            n_layers: 88,
            d_model: 12_288,
            n_heads: 96,
            n_kv_heads: 8,
            vocab_size: 32_768,
            dtype_bytes: 2,
            lora_rank: 8,
            alora_rank: 32,
        },
        gpu: GpuConfig::h100(8),
        cache: CacheConfig {
            block_size: 16,
            max_kv_tokens: 912_688,
            enable_prefix_caching: true,
            base_aligned_hashing: true,
            adapter_paging: false,
            prefix_migration: false,
            adapter_load_bw: 0.0,
            adapter_load_setup: 0.0,
            host_adapter_blocks: 0,
            adapter_prefetch: false,
        },
        scheduler: SchedulerConfig {
            max_batch_tokens: 8192,
            max_num_seqs: 512,
            max_seq_len: 131_072,
            admission_watermark: 1.0,
        },
        seed: 0,
    }
}

/// The paper's baseline: identical engine, but standard-LoRA semantics —
/// adapter blocks always salted (no cross-model reuse) and full re-prefill
/// on every adapter switch. Constructed from any preset.
pub fn lora_baseline_of(mut cfg: EngineConfig) -> EngineConfig {
    cfg.cache.base_aligned_hashing = false;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_kv_capacities() {
        assert_eq!(granite_8b().cache.max_kv_tokens, 351_104);
        assert_eq!(llama_70b().cache.max_kv_tokens, 407_984);
        assert_eq!(mistral_large_2().cache.max_kv_tokens, 912_688);
    }

    #[test]
    fn table1_gpu_counts() {
        assert_eq!(granite_8b().gpu.n_gpus, 1);
        assert_eq!(llama_70b().gpu.n_gpus, 4);
        assert_eq!(mistral_large_2().gpu.n_gpus, 8);
    }

    #[test]
    fn by_name_roundtrip() {
        for name in PRESET_NAMES {
            assert_eq!(by_name(name).unwrap().model.name, *name);
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn baseline_flips_only_hashing() {
        let a = granite_8b();
        let b = lora_baseline_of(granite_8b());
        assert!(!b.cache.base_aligned_hashing);
        assert_eq!(a.model, b.model);
        assert_eq!(a.scheduler, b.scheduler);
    }

    #[test]
    fn tiny_matches_python_config() {
        // Mirrors python/compile/configs.py::TINY; drift is caught again at
        // runtime against manifest.json, but fail fast here too.
        let t = tiny();
        assert_eq!(t.model.vocab_size, 512);
        assert_eq!(t.model.d_model, 128);
        assert_eq!(t.model.n_layers, 4);
        assert_eq!(t.scheduler.max_seq_len, 160);
        assert_eq!(t.cache.block_size, 16);
    }
}
