//! Engine / model / cache / scheduler configuration.
//!
//! A single [`EngineConfig`] drives every entrypoint (CLI, HTTP server,
//! pipelines, figure harness). Presets for the paper's Table-1 testbeds
//! live in [`presets`]; configs can also be loaded from JSON files via
//! [`EngineConfig::from_json`].

pub mod presets;

use crate::util::json::Json;

/// Transformer dimensions + adapter ranks. For the large presets these are
/// inputs to the H100 cost model; for `tiny` they mirror the AOT manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Total parameter count (weights touched per token in decode).
    pub n_params: f64,
    pub n_layers: u32,
    pub d_model: u32,
    pub n_heads: u32,
    /// KV heads (GQA); == n_heads when no grouping.
    pub n_kv_heads: u32,
    pub vocab_size: u32,
    /// Bytes per weight/activation element (bf16 = 2 on the paper's setup,
    /// f32 = 4 on the tiny CPU path).
    pub dtype_bytes: u32,
    /// LoRA adapter rank (paper uses 8).
    pub lora_rank: u32,
    /// aLoRA adapter rank (paper uses 32).
    pub alora_rank: u32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> u32 {
        self.d_model / self.n_heads
    }

    /// KV-cache bytes per token across all layers (both K and V).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.n_layers as f64
            * self.n_kv_heads as f64
            * self.head_dim() as f64
            * self.dtype_bytes as f64
    }
}

/// The GPU substrate the simulator models (paper: NVIDIA H100 80GB HBM3).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuConfig {
    /// Tensor-parallel degree == number of GPUs serving one replica.
    pub n_gpus: u32,
    /// Peak dense bf16 throughput per GPU, FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth per GPU, bytes/s.
    pub hbm_bw: f64,
    /// Achievable model-FLOPs utilization for compute-bound prefill.
    pub prefill_mfu: f64,
    /// Achievable bandwidth utilization for memory-bound decode.
    pub decode_membw_util: f64,
}

impl GpuConfig {
    pub fn h100(n_gpus: u32) -> Self {
        GpuConfig {
            n_gpus,
            peak_flops: 989e12, // H100 SXM dense bf16
            hbm_bw: 3.35e12,    // HBM3
            prefill_mfu: 0.45,
            decode_membw_util: 0.55,
        }
    }

    pub fn total_flops(&self) -> f64 {
        // TP scaling is sub-linear; 0.9 efficiency per the usual NVLink
        // all-reduce overhead at these sizes.
        let eff = if self.n_gpus > 1 { 0.9 } else { 1.0 };
        self.peak_flops * self.n_gpus as f64 * eff
    }

    pub fn total_bw(&self) -> f64 {
        self.hbm_bw * self.n_gpus as f64
    }
}

/// PagedAttention-style cache geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheConfig {
    /// Tokens per KV block (vLLM default 16).
    pub block_size: u32,
    /// Total KV-cache capacity in tokens (paper Table 1 reports these
    /// directly: 351104 / 407984 / 912688).
    pub max_kv_tokens: u64,
    /// Enable automatic prefix caching (hash-based block reuse).
    pub enable_prefix_caching: bool,
    /// THE paper's switch: when true, pre-activation blocks of aLoRA
    /// requests hash *without* the adapter-ID salt, making base and aLoRA
    /// blocks interchangeable (Figure 3). When false, behave like vanilla
    /// vLLM (every adapter block salted) — the LoRA baseline.
    pub base_aligned_hashing: bool,
    /// Unified memory budget (S-LoRA-style): when true, adapter weights
    /// are paged against the SAME block budget as the KV cache — loads
    /// claim pages from the pool, idle adapters are LRU-evicted under
    /// pressure, and admission gates on residency. When false (default),
    /// pre-paging semantics: every adapter is permanently resident and
    /// weight memory is unaccounted (DESIGN.md §13).
    pub adapter_paging: bool,
    /// Cross-replica prefix migration (DESIGN.md §18): when true, a
    /// cluster may ship a session's leased chain to a new home replica —
    /// at a modeled transfer cost charged to the destination's clock —
    /// instead of recomputing the prefix after failover, drain, or a
    /// cross-replica fork, whenever the cost model says transfer beats
    /// prefill. When false (default), replica moves recompute from token
    /// zero, exactly as before this switch existed.
    pub prefix_migration: bool,
    /// Host→device transfer bandwidth for adapter weight loads, bytes/s
    /// (DESIGN.md §20). 0.0 (default) keeps PR-3 semantics: loads are
    /// instantaneous accounting and an admitted cold adapter costs only
    /// the admission stall it always cost — bit-identical to the
    /// pre-tiering engine. A realistic value is PCIe-class, ~25e9.
    pub adapter_load_bw: f64,
    /// Fixed per-load setup cost (s): host-side staging, descriptor
    /// setup, transfer kickoff. Only meaningful with a nonzero
    /// `adapter_load_bw`; promotion from the host tier skips it (the
    /// weights are already staged and pinned).
    pub adapter_load_setup: f64,
    /// Host-memory tier capacity for demoted adapter weights, in the same
    /// KV-block-equivalent units as the device budget (DESIGN.md §20).
    /// 0 (default) disables the tier: device eviction drops weights and
    /// the next use pays a full-cost reload, exactly as before.
    pub host_adapter_blocks: u64,
    /// Adapter prefetch: when true, the scheduler starts loading a queued
    /// request's cold adapter while the request waits for admission,
    /// overlapping transfer with queue time. Off by default; a no-op with
    /// zero `adapter_load_bw` (loads complete instantly anyway).
    pub adapter_prefetch: bool,
}

impl CacheConfig {
    pub fn num_blocks(&self) -> u64 {
        self.max_kv_tokens / self.block_size as u64
    }
}

/// Continuous-batching scheduler knobs (vLLM semantics).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerConfig {
    /// Per-step token budget shared by prefill chunks and decodes
    /// (chunked-prefill: long prefills are split to this granularity and
    /// batched with decodes — Agrawal et al. 2023, paper §2.5).
    pub max_batch_tokens: u32,
    /// Maximum concurrently RUNNING requests.
    pub max_num_seqs: u32,
    /// Upper bound on any request's total sequence length.
    pub max_seq_len: u32,
    /// KV-pressure admission control (paper §4.3: "speedups ... may
    /// require smart allocation of incoming requests to maximize
    /// utilization ... without exceeding memory capacity"). A request is
    /// only admitted if the *projected* block usage — blocks in use plus
    /// the candidate's final-length demand — stays below this fraction of
    /// the pool. 1.0 disables the control (vanilla vLLM behaviour:
    /// admit, then preempt/evict under pressure, destroying reusable
    /// cache). See `figures::ablations::watermark_sweep`.
    pub admission_watermark: f64,
}

/// Fleet-level self-driving knobs (DESIGN.md §19): heartbeat failure
/// detection, routing-summary gossip, and the autoscaler. Lives outside
/// [`EngineConfig`] because it configures the *cluster* control loop, not
/// any single replica; every default reproduces the pre-§19 behavior
/// exactly (live summaries, no monitor-driven failover, fixed fleet).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Consecutive missed heartbeats before a replica is `Suspected`
    /// (routing-penalized, not evacuated).
    pub suspect_after_misses: u32,
    /// Consecutive missed heartbeats before a replica is declared `Down`
    /// and the failover pipeline runs without any admin call. Detection
    /// latency in steps equals this number, exactly.
    pub down_after_misses: u32,
    /// Steps between gossip rounds for routing summaries. 0 = live
    /// gossip: affinity scoring reads each replica's summary directly,
    /// bit-identical to the pre-gossip router (pinned by tests).
    pub gossip_period_steps: u32,
    /// Gossip rounds of staleness tolerated before a snapshot's affinity
    /// score starts decaying toward least-loaded.
    pub gossip_stale_rounds: u32,
    /// Decay slope per round past the staleness bound: a snapshot
    /// `s` rounds past the bound scores `max(0, 1 - slope*s)` of its
    /// affinity value. A stale sketch loses arguments, it never mis-routes.
    pub gossip_decay_slope: f64,
    /// Master switch for the autoscaler control loop.
    pub autoscale: bool,
    /// Fleet never shrinks below this many active replicas.
    pub min_replicas: usize,
    /// Consecutive steps of queue pressure above `queue_high` (per active
    /// replica) before a standby replica is activated.
    pub scale_up_after_steps: u32,
    /// Consecutive steps of queue depth below `queue_low` before the
    /// highest-index active replica starts draining toward standby.
    pub scale_down_after_steps: u32,
    /// Queue-depth-per-active-replica high watermark (scale-up signal;
    /// KV-pool pressure above the admission watermark also counts).
    pub queue_high: f64,
    /// Queue-depth-per-active-replica low watermark (scale-down signal).
    pub queue_low: f64,
    /// Steps after any scale event during which the autoscaler holds.
    pub cooldown_steps: u32,
    /// A freshly activated replica is `warming` — routed overflow only —
    /// until its gossiped summary holds at least this many blocks.
    pub warmup_min_blocks: usize,
    /// Heterogeneous fleet shape (DESIGN.md §20): per-replica overrides
    /// applied positionally at construction. Empty (default) keeps the
    /// uniform fleet — every replica uses the engine config verbatim.
    /// When non-empty the list length must equal the fleet size.
    pub replica_specs: Vec<ReplicaSpec>,
}

/// One replica's deviation from the shared [`EngineConfig`] in a
/// heterogeneous fleet (DESIGN.md §20). Only memory geometry may vary —
/// model/hash config must stay identical or routing's shared chain
/// hashing would silently break (see `Cluster::with_config`).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaSpec {
    /// Device KV budget override in tokens; 0 = keep the engine default.
    pub max_kv_tokens: u64,
    /// Host-tier capacity override in KV-block-equivalents. Applied
    /// verbatim (0 = no host tier on this replica).
    pub host_adapter_blocks: u64,
}

impl ReplicaSpec {
    /// Apply this spec to a replica's engine config.
    pub fn apply(&self, cfg: &mut EngineConfig) {
        if self.max_kv_tokens > 0 {
            cfg.cache.max_kv_tokens = self.max_kv_tokens;
        }
        cfg.cache.host_adapter_blocks = self.host_adapter_blocks;
    }
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            suspect_after_misses: 3,
            down_after_misses: 6,
            gossip_period_steps: 0,
            gossip_stale_rounds: 2,
            gossip_decay_slope: 0.5,
            autoscale: false,
            min_replicas: 1,
            scale_up_after_steps: 8,
            scale_down_after_steps: 64,
            queue_high: 4.0,
            queue_low: 0.5,
            cooldown_steps: 32,
            warmup_min_blocks: 8,
            replica_specs: Vec::new(),
        }
    }
}

impl FleetConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.suspect_after_misses > 0,
            "suspect_after_misses must be > 0"
        );
        anyhow::ensure!(
            self.down_after_misses > self.suspect_after_misses,
            "down_after_misses ({}) must exceed suspect_after_misses ({})",
            self.down_after_misses,
            self.suspect_after_misses
        );
        anyhow::ensure!(self.gossip_decay_slope >= 0.0, "negative decay slope");
        anyhow::ensure!(self.min_replicas > 0, "min_replicas must be > 0");
        anyhow::ensure!(
            self.queue_high > self.queue_low,
            "queue_high must exceed queue_low"
        );
        anyhow::ensure!(self.scale_up_after_steps > 0, "zero scale_up_after_steps");
        anyhow::ensure!(
            self.scale_down_after_steps > 0,
            "zero scale_down_after_steps"
        );
        Ok(())
    }

    /// Load from a JSON object (`serve --fleet-config`); unknown keys are
    /// rejected to catch typos, exactly like `EngineConfig::from_json`.
    pub fn from_json(j: &Json) -> anyhow::Result<FleetConfig> {
        let mut f = FleetConfig::default();
        if let Some(obj) = j.as_obj() {
            for (k, v) in obj {
                match k.as_str() {
                    "suspect_after_misses" => {
                        f.suspect_after_misses =
                            v.as_u64().unwrap_or(f.suspect_after_misses as u64) as u32
                    }
                    "down_after_misses" => {
                        f.down_after_misses =
                            v.as_u64().unwrap_or(f.down_after_misses as u64) as u32
                    }
                    "gossip_period_steps" => {
                        f.gossip_period_steps =
                            v.as_u64().unwrap_or(f.gossip_period_steps as u64) as u32
                    }
                    "gossip_stale_rounds" => {
                        f.gossip_stale_rounds =
                            v.as_u64().unwrap_or(f.gossip_stale_rounds as u64) as u32
                    }
                    "gossip_decay_slope" => {
                        f.gossip_decay_slope = v.as_f64().unwrap_or(f.gossip_decay_slope)
                    }
                    "autoscale" => f.autoscale = v.as_bool().unwrap_or(f.autoscale),
                    "min_replicas" => {
                        f.min_replicas = v.as_u64().unwrap_or(f.min_replicas as u64) as usize
                    }
                    "scale_up_after_steps" => {
                        f.scale_up_after_steps =
                            v.as_u64().unwrap_or(f.scale_up_after_steps as u64) as u32
                    }
                    "scale_down_after_steps" => {
                        f.scale_down_after_steps =
                            v.as_u64().unwrap_or(f.scale_down_after_steps as u64) as u32
                    }
                    "queue_high" => f.queue_high = v.as_f64().unwrap_or(f.queue_high),
                    "queue_low" => f.queue_low = v.as_f64().unwrap_or(f.queue_low),
                    "cooldown_steps" => {
                        f.cooldown_steps = v.as_u64().unwrap_or(f.cooldown_steps as u64) as u32
                    }
                    "warmup_min_blocks" => {
                        f.warmup_min_blocks =
                            v.as_u64().unwrap_or(f.warmup_min_blocks as u64) as usize
                    }
                    "replica_specs" => {
                        let arr = v
                            .as_arr()
                            .ok_or_else(|| anyhow::anyhow!("replica_specs must be an array"))?;
                        f.replica_specs = arr
                            .iter()
                            .map(|s| ReplicaSpec {
                                max_kv_tokens: s
                                    .get("max_kv_tokens")
                                    .and_then(Json::as_u64)
                                    .unwrap_or(0),
                                host_adapter_blocks: s
                                    .get("host_adapter_blocks")
                                    .and_then(Json::as_u64)
                                    .unwrap_or(0),
                            })
                            .collect();
                    }
                    other => anyhow::bail!("unknown fleet config key `{other}`"),
                }
            }
        }
        f.validate()?;
        Ok(f)
    }
}

/// Everything the engine needs.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    pub model: ModelConfig,
    pub gpu: GpuConfig,
    pub cache: CacheConfig,
    pub scheduler: SchedulerConfig,
    /// Random seed for anything stochastic downstream.
    pub seed: u64,
}

impl EngineConfig {
    /// Validate cross-field invariants; called by every constructor path.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.cache.block_size > 0, "block_size must be > 0");
        anyhow::ensure!(
            self.cache.max_kv_tokens >= self.scheduler.max_seq_len as u64,
            "KV capacity ({}) below max_seq_len ({})",
            self.cache.max_kv_tokens,
            self.scheduler.max_seq_len
        );
        anyhow::ensure!(
            self.scheduler.max_seq_len % self.cache.block_size == 0,
            "max_seq_len must be a multiple of block_size"
        );
        anyhow::ensure!(self.scheduler.max_batch_tokens > 0, "zero token budget");
        anyhow::ensure!(self.scheduler.max_num_seqs > 0, "zero max_num_seqs");
        anyhow::ensure!(
            self.scheduler.admission_watermark > 0.0
                && self.scheduler.admission_watermark <= 1.0,
            "admission_watermark must be in (0, 1]"
        );
        anyhow::ensure!(
            self.model.d_model % self.model.n_heads == 0,
            "d_model not divisible by n_heads"
        );
        anyhow::ensure!(
            self.cache.adapter_load_bw >= 0.0,
            "adapter_load_bw must be >= 0"
        );
        anyhow::ensure!(
            self.cache.adapter_load_setup >= 0.0,
            "adapter_load_setup must be >= 0"
        );
        Ok(())
    }

    /// Load from a JSON file. Unknown keys are rejected to catch typos.
    pub fn from_json(j: &Json) -> anyhow::Result<EngineConfig> {
        let preset = j
            .get("preset")
            .and_then(Json::as_str)
            .unwrap_or("granite-8b");
        let mut cfg = presets::by_name(preset)
            .ok_or_else(|| anyhow::anyhow!("unknown preset `{preset}`"))?;
        if let Some(obj) = j.as_obj() {
            for (k, v) in obj {
                match k.as_str() {
                    "preset" => {}
                    "seed" => cfg.seed = v.as_u64().unwrap_or(cfg.seed),
                    "block_size" => {
                        cfg.cache.block_size =
                            v.as_u64().unwrap_or(cfg.cache.block_size as u64) as u32
                    }
                    "max_kv_tokens" => {
                        cfg.cache.max_kv_tokens = v.as_u64().unwrap_or(cfg.cache.max_kv_tokens)
                    }
                    "enable_prefix_caching" => {
                        cfg.cache.enable_prefix_caching =
                            v.as_bool().unwrap_or(cfg.cache.enable_prefix_caching)
                    }
                    "base_aligned_hashing" => {
                        cfg.cache.base_aligned_hashing =
                            v.as_bool().unwrap_or(cfg.cache.base_aligned_hashing)
                    }
                    "adapter_paging" => {
                        cfg.cache.adapter_paging =
                            v.as_bool().unwrap_or(cfg.cache.adapter_paging)
                    }
                    "prefix_migration" => {
                        cfg.cache.prefix_migration =
                            v.as_bool().unwrap_or(cfg.cache.prefix_migration)
                    }
                    "adapter_load_bw" => {
                        cfg.cache.adapter_load_bw =
                            v.as_f64().unwrap_or(cfg.cache.adapter_load_bw)
                    }
                    "adapter_load_setup" => {
                        cfg.cache.adapter_load_setup =
                            v.as_f64().unwrap_or(cfg.cache.adapter_load_setup)
                    }
                    "host_adapter_blocks" => {
                        cfg.cache.host_adapter_blocks =
                            v.as_u64().unwrap_or(cfg.cache.host_adapter_blocks)
                    }
                    "adapter_prefetch" => {
                        cfg.cache.adapter_prefetch =
                            v.as_bool().unwrap_or(cfg.cache.adapter_prefetch)
                    }
                    "max_batch_tokens" => {
                        cfg.scheduler.max_batch_tokens =
                            v.as_u64().unwrap_or(cfg.scheduler.max_batch_tokens as u64) as u32
                    }
                    "max_num_seqs" => {
                        cfg.scheduler.max_num_seqs =
                            v.as_u64().unwrap_or(cfg.scheduler.max_num_seqs as u64) as u32
                    }
                    "max_seq_len" => {
                        cfg.scheduler.max_seq_len =
                            v.as_u64().unwrap_or(cfg.scheduler.max_seq_len as u64) as u32
                    }
                    "admission_watermark" => {
                        cfg.scheduler.admission_watermark =
                            v.as_f64().unwrap_or(cfg.scheduler.admission_watermark)
                    }
                    other => anyhow::bail!("unknown config key `{other}`"),
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for name in presets::PRESET_NAMES {
            let cfg = presets::by_name(name).unwrap();
            cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn kv_bytes_per_token_granite() {
        let cfg = presets::granite_8b();
        // 40 layers * 8 kv heads * 128 head_dim * 2 (K+V) * 2 bytes
        assert_eq!(cfg.model.kv_bytes_per_token(), 163840.0);
    }

    #[test]
    fn from_json_overrides() {
        let j = Json::parse(
            r#"{"preset": "llama-70b", "seed": 9, "base_aligned_hashing": false}"#,
        )
        .unwrap();
        let cfg = EngineConfig::from_json(&j).unwrap();
        assert_eq!(cfg.model.name, "llama-70b");
        assert_eq!(cfg.seed, 9);
        assert!(!cfg.cache.base_aligned_hashing);
    }

    #[test]
    fn from_json_rejects_unknown_keys() {
        let j = Json::parse(r#"{"preset": "tiny", "blok_size": 4}"#).unwrap();
        assert!(EngineConfig::from_json(&j).is_err());
    }

    #[test]
    fn tiering_knobs_default_off_and_parse() {
        let d = presets::tiny();
        assert_eq!(d.cache.adapter_load_bw, 0.0, "default loads are instantaneous");
        assert_eq!(d.cache.adapter_load_setup, 0.0);
        assert_eq!(d.cache.host_adapter_blocks, 0, "default has no host tier");
        assert!(!d.cache.adapter_prefetch);
        let j = Json::parse(
            r#"{"preset": "tiny", "adapter_load_bw": 25e9,
                "adapter_load_setup": 0.002, "host_adapter_blocks": 64,
                "adapter_prefetch": true}"#,
        )
        .unwrap();
        let cfg = EngineConfig::from_json(&j).unwrap();
        assert_eq!(cfg.cache.adapter_load_bw, 25e9);
        assert_eq!(cfg.cache.adapter_load_setup, 0.002);
        assert_eq!(cfg.cache.host_adapter_blocks, 64);
        assert!(cfg.cache.adapter_prefetch);
        let bad = Json::parse(r#"{"preset": "tiny", "adapter_load_bw": -1.0}"#).unwrap();
        assert!(EngineConfig::from_json(&bad).is_err());
    }

    #[test]
    fn replica_specs_parse_and_apply() {
        let j = Json::parse(
            r#"{"replica_specs": [
                {"max_kv_tokens": 4096, "host_adapter_blocks": 32},
                {}
            ]}"#,
        )
        .unwrap();
        let f = FleetConfig::from_json(&j).unwrap();
        assert_eq!(f.replica_specs.len(), 2);
        let mut cfg = presets::tiny();
        f.replica_specs[0].apply(&mut cfg);
        assert_eq!(cfg.cache.max_kv_tokens, 4096);
        assert_eq!(cfg.cache.host_adapter_blocks, 32);
        let mut cfg2 = presets::tiny();
        f.replica_specs[1].apply(&mut cfg2);
        assert_eq!(cfg2.cache.max_kv_tokens, presets::tiny().cache.max_kv_tokens);
        assert_eq!(cfg2.cache.host_adapter_blocks, 0);
    }

    #[test]
    fn validate_rejects_misaligned_seq_len() {
        let mut cfg = presets::tiny();
        cfg.scheduler.max_seq_len = 150; // not multiple of 16
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn fleet_defaults_validate_and_json_roundtrips() {
        let d = FleetConfig::default();
        d.validate().unwrap();
        assert_eq!(d.gossip_period_steps, 0, "default gossip is live");
        assert!(!d.autoscale, "autoscaler is opt-in");
        let j = Json::parse(
            r#"{"autoscale": true, "min_replicas": 2, "gossip_period_steps": 4,
                "suspect_after_misses": 2, "down_after_misses": 5}"#,
        )
        .unwrap();
        let f = FleetConfig::from_json(&j).unwrap();
        assert!(f.autoscale);
        assert_eq!(f.min_replicas, 2);
        assert_eq!(f.gossip_period_steps, 4);
        assert_eq!(f.down_after_misses, 5);
    }

    #[test]
    fn fleet_rejects_unknown_keys_and_bad_thresholds() {
        let j = Json::parse(r#"{"autoscael": true}"#).unwrap();
        assert!(FleetConfig::from_json(&j).is_err());
        let mut f = FleetConfig::default();
        f.down_after_misses = f.suspect_after_misses; // down must be strictly later
        assert!(f.validate().is_err());
        let mut f = FleetConfig::default();
        f.queue_low = f.queue_high + 1.0;
        assert!(f.validate().is_err());
    }

    #[test]
    fn tp_scaling_subunit() {
        let one = GpuConfig::h100(1);
        let four = GpuConfig::h100(4);
        assert!(four.total_flops() < 4.0 * one.total_flops());
        assert!(four.total_flops() > 3.0 * one.total_flops());
    }
}
