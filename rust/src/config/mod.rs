//! Engine / model / cache / scheduler configuration.
//!
//! A single [`EngineConfig`] drives every entrypoint (CLI, HTTP server,
//! pipelines, figure harness). Presets for the paper's Table-1 testbeds
//! live in [`presets`]; configs can also be loaded from JSON files via
//! [`EngineConfig::from_json`].

pub mod presets;

use crate::util::json::Json;

/// Transformer dimensions + adapter ranks. For the large presets these are
/// inputs to the H100 cost model; for `tiny` they mirror the AOT manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Total parameter count (weights touched per token in decode).
    pub n_params: f64,
    pub n_layers: u32,
    pub d_model: u32,
    pub n_heads: u32,
    /// KV heads (GQA); == n_heads when no grouping.
    pub n_kv_heads: u32,
    pub vocab_size: u32,
    /// Bytes per weight/activation element (bf16 = 2 on the paper's setup,
    /// f32 = 4 on the tiny CPU path).
    pub dtype_bytes: u32,
    /// LoRA adapter rank (paper uses 8).
    pub lora_rank: u32,
    /// aLoRA adapter rank (paper uses 32).
    pub alora_rank: u32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> u32 {
        self.d_model / self.n_heads
    }

    /// KV-cache bytes per token across all layers (both K and V).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.n_layers as f64
            * self.n_kv_heads as f64
            * self.head_dim() as f64
            * self.dtype_bytes as f64
    }
}

/// The GPU substrate the simulator models (paper: NVIDIA H100 80GB HBM3).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuConfig {
    /// Tensor-parallel degree == number of GPUs serving one replica.
    pub n_gpus: u32,
    /// Peak dense bf16 throughput per GPU, FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth per GPU, bytes/s.
    pub hbm_bw: f64,
    /// Achievable model-FLOPs utilization for compute-bound prefill.
    pub prefill_mfu: f64,
    /// Achievable bandwidth utilization for memory-bound decode.
    pub decode_membw_util: f64,
}

impl GpuConfig {
    pub fn h100(n_gpus: u32) -> Self {
        GpuConfig {
            n_gpus,
            peak_flops: 989e12, // H100 SXM dense bf16
            hbm_bw: 3.35e12,    // HBM3
            prefill_mfu: 0.45,
            decode_membw_util: 0.55,
        }
    }

    pub fn total_flops(&self) -> f64 {
        // TP scaling is sub-linear; 0.9 efficiency per the usual NVLink
        // all-reduce overhead at these sizes.
        let eff = if self.n_gpus > 1 { 0.9 } else { 1.0 };
        self.peak_flops * self.n_gpus as f64 * eff
    }

    pub fn total_bw(&self) -> f64 {
        self.hbm_bw * self.n_gpus as f64
    }
}

/// PagedAttention-style cache geometry.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheConfig {
    /// Tokens per KV block (vLLM default 16).
    pub block_size: u32,
    /// Total KV-cache capacity in tokens (paper Table 1 reports these
    /// directly: 351104 / 407984 / 912688).
    pub max_kv_tokens: u64,
    /// Enable automatic prefix caching (hash-based block reuse).
    pub enable_prefix_caching: bool,
    /// THE paper's switch: when true, pre-activation blocks of aLoRA
    /// requests hash *without* the adapter-ID salt, making base and aLoRA
    /// blocks interchangeable (Figure 3). When false, behave like vanilla
    /// vLLM (every adapter block salted) — the LoRA baseline.
    pub base_aligned_hashing: bool,
    /// Unified memory budget (S-LoRA-style): when true, adapter weights
    /// are paged against the SAME block budget as the KV cache — loads
    /// claim pages from the pool, idle adapters are LRU-evicted under
    /// pressure, and admission gates on residency. When false (default),
    /// pre-paging semantics: every adapter is permanently resident and
    /// weight memory is unaccounted (DESIGN.md §13).
    pub adapter_paging: bool,
    /// Cross-replica prefix migration (DESIGN.md §18): when true, a
    /// cluster may ship a session's leased chain to a new home replica —
    /// at a modeled transfer cost charged to the destination's clock —
    /// instead of recomputing the prefix after failover, drain, or a
    /// cross-replica fork, whenever the cost model says transfer beats
    /// prefill. When false (default), replica moves recompute from token
    /// zero, exactly as before this switch existed.
    pub prefix_migration: bool,
}

impl CacheConfig {
    pub fn num_blocks(&self) -> u64 {
        self.max_kv_tokens / self.block_size as u64
    }
}

/// Continuous-batching scheduler knobs (vLLM semantics).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerConfig {
    /// Per-step token budget shared by prefill chunks and decodes
    /// (chunked-prefill: long prefills are split to this granularity and
    /// batched with decodes — Agrawal et al. 2023, paper §2.5).
    pub max_batch_tokens: u32,
    /// Maximum concurrently RUNNING requests.
    pub max_num_seqs: u32,
    /// Upper bound on any request's total sequence length.
    pub max_seq_len: u32,
    /// KV-pressure admission control (paper §4.3: "speedups ... may
    /// require smart allocation of incoming requests to maximize
    /// utilization ... without exceeding memory capacity"). A request is
    /// only admitted if the *projected* block usage — blocks in use plus
    /// the candidate's final-length demand — stays below this fraction of
    /// the pool. 1.0 disables the control (vanilla vLLM behaviour:
    /// admit, then preempt/evict under pressure, destroying reusable
    /// cache). See `figures::ablations::watermark_sweep`.
    pub admission_watermark: f64,
}

/// Everything the engine needs.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    pub model: ModelConfig,
    pub gpu: GpuConfig,
    pub cache: CacheConfig,
    pub scheduler: SchedulerConfig,
    /// Random seed for anything stochastic downstream.
    pub seed: u64,
}

impl EngineConfig {
    /// Validate cross-field invariants; called by every constructor path.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.cache.block_size > 0, "block_size must be > 0");
        anyhow::ensure!(
            self.cache.max_kv_tokens >= self.scheduler.max_seq_len as u64,
            "KV capacity ({}) below max_seq_len ({})",
            self.cache.max_kv_tokens,
            self.scheduler.max_seq_len
        );
        anyhow::ensure!(
            self.scheduler.max_seq_len % self.cache.block_size == 0,
            "max_seq_len must be a multiple of block_size"
        );
        anyhow::ensure!(self.scheduler.max_batch_tokens > 0, "zero token budget");
        anyhow::ensure!(self.scheduler.max_num_seqs > 0, "zero max_num_seqs");
        anyhow::ensure!(
            self.scheduler.admission_watermark > 0.0
                && self.scheduler.admission_watermark <= 1.0,
            "admission_watermark must be in (0, 1]"
        );
        anyhow::ensure!(
            self.model.d_model % self.model.n_heads == 0,
            "d_model not divisible by n_heads"
        );
        Ok(())
    }

    /// Load from a JSON file. Unknown keys are rejected to catch typos.
    pub fn from_json(j: &Json) -> anyhow::Result<EngineConfig> {
        let preset = j
            .get("preset")
            .and_then(Json::as_str)
            .unwrap_or("granite-8b");
        let mut cfg = presets::by_name(preset)
            .ok_or_else(|| anyhow::anyhow!("unknown preset `{preset}`"))?;
        if let Some(obj) = j.as_obj() {
            for (k, v) in obj {
                match k.as_str() {
                    "preset" => {}
                    "seed" => cfg.seed = v.as_u64().unwrap_or(cfg.seed),
                    "block_size" => {
                        cfg.cache.block_size =
                            v.as_u64().unwrap_or(cfg.cache.block_size as u64) as u32
                    }
                    "max_kv_tokens" => {
                        cfg.cache.max_kv_tokens = v.as_u64().unwrap_or(cfg.cache.max_kv_tokens)
                    }
                    "enable_prefix_caching" => {
                        cfg.cache.enable_prefix_caching =
                            v.as_bool().unwrap_or(cfg.cache.enable_prefix_caching)
                    }
                    "base_aligned_hashing" => {
                        cfg.cache.base_aligned_hashing =
                            v.as_bool().unwrap_or(cfg.cache.base_aligned_hashing)
                    }
                    "adapter_paging" => {
                        cfg.cache.adapter_paging =
                            v.as_bool().unwrap_or(cfg.cache.adapter_paging)
                    }
                    "prefix_migration" => {
                        cfg.cache.prefix_migration =
                            v.as_bool().unwrap_or(cfg.cache.prefix_migration)
                    }
                    "max_batch_tokens" => {
                        cfg.scheduler.max_batch_tokens =
                            v.as_u64().unwrap_or(cfg.scheduler.max_batch_tokens as u64) as u32
                    }
                    "max_num_seqs" => {
                        cfg.scheduler.max_num_seqs =
                            v.as_u64().unwrap_or(cfg.scheduler.max_num_seqs as u64) as u32
                    }
                    "max_seq_len" => {
                        cfg.scheduler.max_seq_len =
                            v.as_u64().unwrap_or(cfg.scheduler.max_seq_len as u64) as u32
                    }
                    "admission_watermark" => {
                        cfg.scheduler.admission_watermark =
                            v.as_f64().unwrap_or(cfg.scheduler.admission_watermark)
                    }
                    other => anyhow::bail!("unknown config key `{other}`"),
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for name in presets::PRESET_NAMES {
            let cfg = presets::by_name(name).unwrap();
            cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn kv_bytes_per_token_granite() {
        let cfg = presets::granite_8b();
        // 40 layers * 8 kv heads * 128 head_dim * 2 (K+V) * 2 bytes
        assert_eq!(cfg.model.kv_bytes_per_token(), 163840.0);
    }

    #[test]
    fn from_json_overrides() {
        let j = Json::parse(
            r#"{"preset": "llama-70b", "seed": 9, "base_aligned_hashing": false}"#,
        )
        .unwrap();
        let cfg = EngineConfig::from_json(&j).unwrap();
        assert_eq!(cfg.model.name, "llama-70b");
        assert_eq!(cfg.seed, 9);
        assert!(!cfg.cache.base_aligned_hashing);
    }

    #[test]
    fn from_json_rejects_unknown_keys() {
        let j = Json::parse(r#"{"preset": "tiny", "blok_size": 4}"#).unwrap();
        assert!(EngineConfig::from_json(&j).is_err());
    }

    #[test]
    fn validate_rejects_misaligned_seq_len() {
        let mut cfg = presets::tiny();
        cfg.scheduler.max_seq_len = 150; // not multiple of 16
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn tp_scaling_subunit() {
        let one = GpuConfig::h100(1);
        let four = GpuConfig::h100(4);
        assert!(four.total_flops() < 4.0 * one.total_flops());
        assert!(four.total_flops() > 3.0 * one.total_flops());
    }
}
