//! Unified GPU-memory accounting: one ledger for KV pages AND adapter
//! weights.
//!
//! The paper's speedups come from never recomputing KV across adapter
//! switches, but a multi-adapter server's device memory is not spent on KV
//! alone: every resident adapter's LoRA weights live in the same HBM the
//! block pool carves up. S-LoRA (arXiv 2311.03285) makes this explicit —
//! adapter weights are paged in a *unified memory pool* alongside KV cache,
//! which is what lets thousands of adapters share one GPU — and
//! FASTLIBRA-style co-management (arXiv 2505.03756) shows the two must be
//! evicted under one policy, not two independent ones.
//!
//! [`MemoryBudget`] is that single ledger, denominated in KV blocks (the
//! pool's native page size). It is owned by [`crate::kvcache::BlockPool`]
//! and split two ways:
//!
//! - **KV side**: implicit — pool blocks not claimed by adapters. The pool's
//!   free list remains the one physical allocator; nothing is counted twice.
//! - **Adapter side**: [`MemoryBudget::adapter_blocks`] pages claimed by
//!   resident adapter weights via `BlockPool::claim_blocks` (which pulls
//!   from the SAME LRU free list a KV allocation would, evicting cold
//!   cached content but never a referenced block).
//!
//! Because both sides draw from one free list, the co-management property
//! falls out structurally: evicting a cold adapter returns its pages to the
//! free list and immediately raises KV headroom, and freeing KV raises the
//! headroom an adapter load sees. Policy (which adapter to evict, when to
//! stall admission) lives in [`crate::adapter::residency::AdapterResidency`]
//! and the scheduler; this module is the accounting substrate.

/// The memory ledger, denominated in KV-block-equivalents. Two tiers
/// (DESIGN.md §20):
///
/// - **Device**: the pool's physical arena, split between KV pages and
///   resident adapter weights. Invariant: `adapter_blocks <=
///   total_blocks`, and physically the pool guarantees `adapter_blocks +
///   kv_referenced + free == total_blocks` (checked by
///   `BlockPool::check_invariants`).
/// - **Host**: a SEPARATE capacity for demoted adapter weights parked in
///   pinned host memory awaiting cheap promotion. Host blocks are purely
///   modeled (no physical `BlockId`s — the pool never sees them), so the
///   device invariant above is untouched by the tier. Invariant:
///   `host_blocks <= host_total_blocks`; a zero-capacity host tier
///   (the default) can never be charged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryBudget {
    total_blocks: usize,
    adapter_blocks: usize,
    host_total_blocks: usize,
    host_blocks: usize,
}

impl MemoryBudget {
    pub fn new(total_blocks: usize) -> Self {
        MemoryBudget {
            total_blocks,
            adapter_blocks: 0,
            host_total_blocks: 0,
            host_blocks: 0,
        }
    }

    /// Set the host-tier capacity (construction-time; DESIGN.md §20).
    pub(crate) fn set_host_capacity(&mut self, blocks: usize) {
        assert!(
            self.host_blocks <= blocks,
            "shrinking host tier below {} charged blocks",
            self.host_blocks
        );
        self.host_total_blocks = blocks;
    }

    /// Whole-device capacity in blocks (KV arena size at construction).
    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Blocks currently charged to resident adapter weights.
    pub fn adapter_blocks(&self) -> usize {
        self.adapter_blocks
    }

    /// Blocks the KV side may grow into once adapters are accounted —
    /// the *capacity* split, not instantaneous free space (the pool's
    /// free list reports that).
    pub fn kv_capacity_blocks(&self) -> usize {
        self.total_blocks - self.adapter_blocks
    }

    /// Charge `n` blocks to the adapter side (a weight load).
    pub(crate) fn charge_adapter(&mut self, n: usize) {
        assert!(
            self.adapter_blocks + n <= self.total_blocks,
            "adapter charge {n} over budget ({} of {} already charged)",
            self.adapter_blocks,
            self.total_blocks
        );
        self.adapter_blocks += n;
    }

    /// Return `n` blocks from the adapter side (a weight eviction).
    pub(crate) fn release_adapter(&mut self, n: usize) {
        assert!(n <= self.adapter_blocks, "adapter release {n} without charge");
        self.adapter_blocks -= n;
    }

    /// Host-tier capacity in blocks (0 = tier disabled).
    pub fn host_total_blocks(&self) -> usize {
        self.host_total_blocks
    }

    /// Blocks currently charged to demoted adapter weights on the host.
    pub fn host_blocks(&self) -> usize {
        self.host_blocks
    }

    /// Host-tier headroom.
    pub fn host_free_blocks(&self) -> usize {
        self.host_total_blocks - self.host_blocks
    }

    /// Charge `n` blocks to the host tier (a demotion). Returns false —
    /// charging nothing — when the tier lacks headroom; the caller
    /// decides what to drop (residency's host-LRU).
    pub(crate) fn try_charge_host(&mut self, n: usize) -> bool {
        if self.host_blocks + n > self.host_total_blocks {
            return false;
        }
        self.host_blocks += n;
        true
    }

    /// Return `n` blocks from the host tier (a promotion or a drop).
    pub(crate) fn release_host(&mut self, n: usize) {
        assert!(n <= self.host_blocks, "host release {n} without charge");
        self.host_blocks -= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_release_roundtrip() {
        let mut b = MemoryBudget::new(10);
        assert_eq!(b.total_blocks(), 10);
        assert_eq!(b.adapter_blocks(), 0);
        assert_eq!(b.kv_capacity_blocks(), 10);
        b.charge_adapter(3);
        assert_eq!(b.adapter_blocks(), 3);
        assert_eq!(b.kv_capacity_blocks(), 7);
        b.charge_adapter(7);
        assert_eq!(b.kv_capacity_blocks(), 0);
        b.release_adapter(10);
        assert_eq!(b.adapter_blocks(), 0);
        assert_eq!(b.kv_capacity_blocks(), 10);
    }

    #[test]
    #[should_panic(expected = "over budget")]
    fn overcharge_panics() {
        let mut b = MemoryBudget::new(4);
        b.charge_adapter(5);
    }

    #[test]
    #[should_panic(expected = "without charge")]
    fn release_without_charge_panics() {
        let mut b = MemoryBudget::new(4);
        b.release_adapter(1);
    }

    #[test]
    fn host_tier_charges_independently_of_device() {
        let mut b = MemoryBudget::new(10);
        assert_eq!(b.host_total_blocks(), 0);
        assert!(!b.try_charge_host(1), "zero-capacity tier never charges");
        b.set_host_capacity(6);
        assert_eq!(b.host_free_blocks(), 6);
        assert!(b.try_charge_host(4));
        assert_eq!(b.host_blocks(), 4);
        assert!(!b.try_charge_host(3), "over host capacity");
        assert_eq!(b.host_blocks(), 4, "failed charge mutates nothing");
        // Host tier never touches the device split.
        assert_eq!(b.adapter_blocks(), 0);
        assert_eq!(b.kv_capacity_blocks(), 10);
        b.release_host(4);
        assert_eq!(b.host_blocks(), 0);
        assert_eq!(b.host_free_blocks(), 6);
    }

    #[test]
    #[should_panic(expected = "host release")]
    fn host_release_without_charge_panics() {
        let mut b = MemoryBudget::new(4);
        b.set_host_capacity(2);
        b.release_host(1);
    }
}
