//! Streaming and batch statistics for latency metrics and benches.
//!
//! Replaces the (unavailable) criterion/hdrhistogram stack: Welford running
//! moments, exact percentiles over retained samples, and a fixed-bucket
//! log-scale histogram for the Prometheus exposition path.

/// Running mean/variance via Welford; O(1) memory.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Sample set with exact percentiles. Used for per-stage latency summaries
/// (Table 2 metrics) where request counts are modest.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Samples { xs: Vec::new(), sorted: true }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend_from(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() { 0.0 } else { self.sum() / self.xs.len() as f64 }
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
    }

    /// Exact percentile by linear interpolation; `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = (p / 100.0) * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let frac = rank - lo as f64;
            self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn min(&mut self) -> f64 {
        self.percentile(0.0)
    }
    pub fn max(&mut self) -> f64 {
        self.percentile(100.0)
    }
}

/// Log-scale latency histogram (seconds), Prometheus-style cumulative
/// buckets. Bounds follow vLLM's request-latency buckets.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        let bounds = vec![
            0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
            2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
        ];
        let n = bounds.len();
        LatencyHistogram { bounds, counts: vec![0; n + 1], sum: 0.0, total: 0 }
    }

    #[inline]
    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Merge another histogram (identical bucket layout by construction).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(self.bounds, other.bounds, "histogram layouts differ");
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.sum += other.sum;
        self.total += other.total;
    }

    /// (upper_bound, cumulative_count) pairs, ending with (+Inf, total).
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.bounds.len() + 1);
        let mut acc = 0;
        for (i, &b) in self.bounds.iter().enumerate() {
            acc += self.counts[i];
            out.push((b, acc));
        }
        out.push((f64::INFINITY, self.total));
        out
    }
}

/// Geometric-ish sweep helper: e.g. `[128, 256, ..., 65536]` prompt lengths.
pub fn pow2_sweep(lo: u64, hi: u64) -> Vec<u64> {
    let mut v = Vec::new();
    let mut x = lo;
    while x <= hi {
        v.push(x);
        x *= 2;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            all.push(x);
            if i % 2 == 0 { a.push(x) } else { b.push(x) }
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.p99() - 99.01).abs() < 0.02);
    }

    #[test]
    fn percentile_empty_is_zero() {
        let mut s = Samples::new();
        assert_eq!(s.median(), 0.0);
    }

    #[test]
    fn histogram_cumulative() {
        let mut h = LatencyHistogram::new();
        h.observe(0.0005);
        h.observe(0.3);
        h.observe(700.0);
        assert_eq!(h.count(), 3);
        let cum = h.cumulative();
        assert_eq!(cum.first().unwrap().1, 1); // <= 1ms
        assert_eq!(cum.last().unwrap().1, 3); // +Inf
        let at_half = cum.iter().find(|(b, _)| *b == 0.5).unwrap().1;
        assert_eq!(at_half, 2);
    }

    #[test]
    fn pow2_sweep_bounds() {
        assert_eq!(pow2_sweep(128, 1024), vec![128, 256, 512, 1024]);
        assert_eq!(pow2_sweep(64, 64), vec![64]);
    }
}
