//! Streaming and batch statistics for latency metrics and benches.
//!
//! Replaces the (unavailable) criterion/hdrhistogram stack: Welford running
//! moments, exact percentiles over retained samples, and a fixed-bucket
//! log-scale histogram for the Prometheus exposition path.

/// Running mean/variance via Welford; O(1) memory.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Retained samples per series. Exact percentiles below this; a seeded
/// reservoir (algorithm R) above it, so per-series memory is O(1) no
/// matter how many observations flow through (the million-session bound).
pub const RESERVOIR_CAP: usize = 4096;

/// Sample set with bounded memory: exact count/sum/min/max always, exact
/// percentiles while under [`RESERVOIR_CAP`], reservoir-sampled percentiles
/// beyond it. Replacement decisions come from a private splitmix64 stream
/// with a fixed seed, so quantiles are bit-identical across runs.
#[derive(Debug, Clone)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
    rng: u64,
}

impl Default for Samples {
    fn default() -> Self {
        Self::new()
    }
}

impl Samples {
    pub fn new() -> Self {
        Samples {
            xs: Vec::new(),
            sorted: true,
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            rng: 0x5A4D_9E37_C0FF_EE01,
        }
    }

    #[inline]
    fn next_rand(&mut self) -> u64 {
        self.rng = self.rng.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.rng;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        if self.xs.len() < RESERVOIR_CAP {
            self.xs.push(x);
            self.sorted = false;
        } else {
            // Algorithm R: the i-th observation replaces a retained slot
            // with probability cap/i, keeping the reservoir a uniform
            // sample of the whole stream.
            let j = self.next_rand() % self.n;
            if (j as usize) < RESERVOIR_CAP {
                self.xs[j as usize] = x;
                self.sorted = false;
            }
        }
    }

    /// Merge another sample set. Exact while the combined count fits the
    /// reservoir; beyond that the merged reservoir draws each slot from
    /// one side with probability proportional to its true count, so
    /// quantiles stay weighted by observation volume, not retention.
    pub fn extend_from(&mut self, other: &Samples) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let total = self.n + other.n;
        if total as usize <= RESERVOIR_CAP {
            // Both sides are below cap, hence exact.
            self.xs.extend_from_slice(&other.xs);
            self.sorted = false;
        } else {
            let mut merged = Vec::with_capacity(RESERVOIR_CAP);
            for _ in 0..RESERVOIR_CAP {
                let from_self = self.next_rand() % total < self.n;
                let src = if from_self { &self.xs } else { &other.xs };
                let j = (self.next_rand() % src.len() as u64) as usize;
                merged.push(src[j]);
            }
            self.xs = merged;
            self.sorted = false;
        }
        self.n = total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Exact observation count (not the retained-sample count).
    pub fn len(&self) -> usize {
        self.n as usize
    }
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Retained samples — bounded by [`RESERVOIR_CAP`] (memory audits).
    pub fn retained(&self) -> usize {
        self.xs.len()
    }

    /// Exact running sum.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean (sum/count — not reservoir-approximated).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
    }

    /// Percentile by linear interpolation over the retained samples; `p`
    /// in [0, 100]. Exact below [`RESERVOIR_CAP`]; the endpoints are
    /// always exact (tracked min/max).
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if p <= 0.0 {
            return self.min;
        }
        if p >= 100.0 {
            return self.max;
        }
        self.ensure_sorted();
        let rank = (p / 100.0) * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let frac = rank - lo as f64;
            self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// Exact minimum (0.0 when empty).
    pub fn min(&mut self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    /// Exact maximum (0.0 when empty).
    pub fn max(&mut self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Log-scale latency histogram (seconds), Prometheus-style cumulative
/// buckets. Bounds follow vLLM's request-latency buckets.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        let bounds = vec![
            0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
            2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
        ];
        let n = bounds.len();
        LatencyHistogram { bounds, counts: vec![0; n + 1], sum: 0.0, total: 0 }
    }

    #[inline]
    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Merge another histogram (identical bucket layout by construction).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(self.bounds, other.bounds, "histogram layouts differ");
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.sum += other.sum;
        self.total += other.total;
    }

    /// (upper_bound, cumulative_count) pairs, ending with (+Inf, total).
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.bounds.len() + 1);
        let mut acc = 0;
        for (i, &b) in self.bounds.iter().enumerate() {
            acc += self.counts[i];
            out.push((b, acc));
        }
        out.push((f64::INFINITY, self.total));
        out
    }
}

/// Geometric-ish sweep helper: e.g. `[128, 256, ..., 65536]` prompt lengths.
pub fn pow2_sweep(lo: u64, hi: u64) -> Vec<u64> {
    let mut v = Vec::new();
    let mut x = lo;
    while x <= hi {
        v.push(x);
        x *= 2;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            all.push(x);
            if i % 2 == 0 { a.push(x) } else { b.push(x) }
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.p99() - 99.01).abs() < 0.02);
    }

    #[test]
    fn percentile_empty_is_zero() {
        let mut s = Samples::new();
        assert_eq!(s.median(), 0.0);
    }

    #[test]
    fn reservoir_bounds_memory_and_keeps_exact_moments() {
        let mut s = Samples::new();
        let n = 100_000u64;
        for i in 0..n {
            s.push(i as f64);
        }
        assert_eq!(s.len(), n as usize, "count is exact");
        assert!(s.retained() <= RESERVOIR_CAP, "memory bounded");
        assert!((s.sum() - (n * (n - 1) / 2) as f64).abs() < 1e-3);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), (n - 1) as f64);
        assert!((s.mean() - (n - 1) as f64 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn reservoir_quantiles_near_exact_and_deterministic() {
        // Uniform 0..100k: reservoir p50/p99 must land within a few
        // percent of truth, and two identical runs must agree bit-for-bit
        // (fixed seed — the determinism contract figures rely on).
        let run = || {
            let mut s = Samples::new();
            for i in 0..100_000u64 {
                // Bit-mixed insertion order so sortedness isn't an accident.
                let x = (i.wrapping_mul(0x9E37_79B9_7F4A_7C15) % 100_000) as f64;
                s.push(x);
            }
            (s.median(), s.p99())
        };
        let (m1, p1) = run();
        let (m2, p2) = run();
        assert_eq!(m1.to_bits(), m2.to_bits(), "median deterministic");
        assert_eq!(p1.to_bits(), p2.to_bits(), "p99 deterministic");
        assert!((m1 - 50_000.0).abs() < 3_000.0, "median={m1}");
        assert!((p1 - 99_000.0).abs() < 1_500.0, "p99={p1}");
    }

    #[test]
    fn reservoir_merge_weights_by_count() {
        // 90k low values + 10k high values merged over-cap: p50 must stay
        // low (count-weighted), and the merge must be deterministic.
        let build = || {
            let mut a = Samples::new();
            for i in 0..90_000 {
                a.push((i % 100) as f64);
            }
            let mut b = Samples::new();
            for i in 0..10_000 {
                b.push(1_000.0 + (i % 100) as f64);
            }
            a.extend_from(&b);
            a
        };
        let mut m = build();
        let mut m2 = build();
        assert_eq!(m.len(), 100_000);
        assert!(m.retained() <= RESERVOIR_CAP);
        assert_eq!(m.median().to_bits(), m2.median().to_bits());
        assert!(m.median() < 200.0, "median weighted to the 90% side");
        assert_eq!(m.min(), 0.0);
        assert_eq!(m.max(), 1_099.0);
    }

    #[test]
    fn property_reservoir_quantiles_track_exact() {
        // Satellite (c): on random distributions, reservoir quantiles stay
        // within tolerance of an exact (unbounded) computation, and repeat
        // runs are bit-identical.
        use crate::util::prop;
        prop::check("reservoir-quantiles", 8, |rng, _| {
            let n = rng.range(20_000, 60_000) as usize;
            let scale = rng.range(1, 1000) as f64;
            let xs: Vec<f64> = (0..n)
                .map(|_| match rng.next_below(3) {
                    0 => rng.next_f64() * scale,
                    1 => rng.exponential(1.0 / scale),
                    _ => rng.gaussian().abs() * scale,
                })
                .collect();
            let mut exact = xs.clone();
            exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let exact_at = |p: f64| exact[((p / 100.0) * (n - 1) as f64) as usize];
            let fill = |xs: &[f64]| {
                let mut s = Samples::new();
                for &x in xs {
                    s.push(x);
                }
                s
            };
            let mut s = fill(&xs);
            let mut s2 = fill(&xs);
            for p in [50.0, 90.0, 99.0] {
                let got = s.percentile(p);
                let want = exact_at(p);
                let tol = 0.15 * (exact_at(99.9) - exact_at(0.1)).max(1e-9);
                if (got - want).abs() > tol {
                    return Err(format!("p{p}: got {got}, exact {want}, tol {tol}"));
                }
                if got.to_bits() != s2.percentile(p).to_bits() {
                    return Err(format!("p{p} not deterministic"));
                }
            }
            if s.len() != n || s.retained() > RESERVOIR_CAP {
                return Err("count/retention broken".into());
            }
            Ok(())
        });
    }

    #[test]
    fn small_merges_stay_exact() {
        let mut a = Samples::new();
        let mut b = Samples::new();
        for i in 1..=50 {
            a.push(i as f64);
        }
        for i in 51..=100 {
            b.push(i as f64);
        }
        a.extend_from(&b);
        assert_eq!(a.len(), 100);
        assert!((a.median() - 50.5).abs() < 1e-9);
        assert_eq!(a.percentile(100.0), 100.0);
    }

    #[test]
    fn histogram_cumulative() {
        let mut h = LatencyHistogram::new();
        h.observe(0.0005);
        h.observe(0.3);
        h.observe(700.0);
        assert_eq!(h.count(), 3);
        let cum = h.cumulative();
        assert_eq!(cum.first().unwrap().1, 1); // <= 1ms
        assert_eq!(cum.last().unwrap().1, 3); // +Inf
        let at_half = cum.iter().find(|(b, _)| *b == 0.5).unwrap().1;
        assert_eq!(at_half, 2);
    }

    #[test]
    fn pow2_sweep_bounds() {
        assert_eq!(pow2_sweep(128, 1024), vec![128, 256, 512, 1024]);
        assert_eq!(pow2_sweep(64, 64), vec![64]);
    }
}
