//! Dependency-light infrastructure: PRNG, stats, JSON, property testing,
//! bench harness. See DESIGN.md §7 — the offline build environment lacks
//! rand/serde/criterion/proptest, so these are first-class modules with
//! their own test suites rather than vendored shims.

pub mod bench;
pub mod fxmap;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
