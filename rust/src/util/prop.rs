//! Seeded property-testing mini-framework (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs `cases` generated inputs; on failure
//! it reports the case seed so the exact input is reproducible with
//! `replay(seed, ...)`. No shrinking — generators are encouraged to start
//! small and scale with the case index, which keeps early counterexamples
//! readable.

use super::rng::Rng;

/// Outcome of one property case.
pub type PropResult = Result<(), String>;

/// Run `cases` seeded property cases. Panics (test failure) with the
/// offending seed embedded in the message.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng, u64) -> PropResult,
{
    for i in 0..cases {
        let seed = 0xA10A_5EED ^ (i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng, i) {
            panic!(
                "property `{name}` failed on case {i} (seed {seed:#x}): {msg}\n\
                 replay with util::prop::replay({seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng, u64) -> PropResult,
{
    let mut rng = Rng::new(seed);
    f(&mut rng, 0).expect("replayed case still failing");
}

/// Assert helper producing PropResult.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 25, |rng, _| {
            n += 1;
            let x = rng.next_below(100);
            prop_assert!(x < 100, "x={x}");
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng, _| {
            let x = rng.next_below(10);
            prop_assert!(x < 5, "x={x} >= 5");
            Ok(())
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check("collect", 5, |rng, _| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("collect", 5, |rng, _| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
