//! Deterministic PRNG + distribution sampling.
//!
//! The offline build environment ships no `rand` crate, so we carry our own
//! xoshiro256** (public-domain reference algorithm by Blackman/Vigna) seeded
//! via splitmix64. Everything stochastic in the repo — synthetic prompts,
//! Poisson arrivals, adapter choice — flows through this type, which is what
//! makes figures and tests reproducible bit-for-bit (DESIGN.md §9.5).

/// xoshiro256** seeded deterministically via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-request / per-trial RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n). Unbiased via rejection (Lemire-style threshold).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        lo + self.next_below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high-quality mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Inter-arrival times
    /// of the paper's Poisson request process (§4.3).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        // Avoid ln(0): 1 - u in (0, 1].
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Poisson-distributed count (Knuth for small mean, normal approx for
    /// large — only used for workload shaping, not on the hot path).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        // normal approximation with continuity correction
        let g = self.gaussian();
        let v = mean + mean.sqrt() * g + 0.5;
        if v < 0.0 {
            0
        } else {
            v as u64
        }
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Random token ids in [0, vocab), avoiding the reserved top range used
    /// for invocation sequences when `reserve_top` > 0.
    pub fn tokens(&mut self, n: usize, vocab: u32, reserve_top: u32) -> Vec<u32> {
        let hi = vocab.saturating_sub(reserve_top).max(1) as u64;
        (0..n).map(|_| self.next_below(hi) as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval_with_reasonable_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(11);
        let lambda = 4.0;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(13);
        for &m in &[0.5, 5.0, 80.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.poisson(m) as f64).sum::<f64>() / n as f64;
            assert!((mean - m).abs() < m.max(1.0) * 0.05, "m={m} mean={mean}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn tokens_respect_reserved_range() {
        let mut r = Rng::new(19);
        let toks = r.tokens(500, 512, 64);
        assert_eq!(toks.len(), 500);
        assert!(toks.iter().all(|&t| t < 448));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
