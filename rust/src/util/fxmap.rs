//! FxHash-style HashMap/HashSet for hot-path integer keys.
//!
//! std's default SipHash is DoS-resistant but ~5× slower than a
//! multiply-rotate mix for the u64 keys on the engine's critical path
//! (request IDs, block hashes — the latter are *already* uniformly mixed
//! by kvcache::hash). Perf-pass change; see EXPERIMENTS.md §Perf.

use std::hash::{BuildHasherDefault, Hasher};

const K: u64 = 0x517c_c1b7_2722_0a95;

/// Multiply-rotate hasher (FxHash algorithm, as used by rustc).
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, w: u64) {
        self.hash = (self.hash.rotate_left(5) ^ w).wrapping_mul(K);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<T> = std::collections::HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_like_std() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert(i, (i * 3) as u32);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m[&i], (i * 3) as u32);
        }
        m.remove(&500);
        assert!(!m.contains_key(&500));
    }

    #[test]
    fn hash_distribution_reasonable() {
        // low collision rate over sequential keys in a 1024-bucket space
        use std::hash::{BuildHasher, Hash};
        let bh = FxBuildHasher::default();
        let mut buckets = vec![0u32; 1024];
        for i in 0..4096u64 {
            let mut h = bh.build_hasher();
            i.hash(&mut h);
            buckets[(h.finish() % 1024) as usize] += 1;
        }
        let max = *buckets.iter().max().unwrap();
        assert!(max < 24, "bucket skew: {max}");
    }
}
