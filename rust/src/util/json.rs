//! Minimal JSON: parse + serialize, no external deps.
//!
//! Used to read the AOT artifacts' `manifest.json` / `golden.json`, to load
//! engine config files, and to dump figure results. Full JSON grammar with
//! the usual escapes; numbers parse as f64 with integer accessors.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that panics with a useful message — for trusted
    /// artifact files where absence is a build error, not a runtime case.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 { Some(x as u64) } else { None }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| {
            if x.fract() == 0.0 { Some(x as i64) } else { None }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64> (golden logit heads etc.).
    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    pub fn u32_vec(&self) -> Option<Vec<u32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_u64().map(|x| x as u32))
            .collect()
    }

    // -- construction ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // -- parse -------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text)?)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // BMP only; surrogate pairs unsupported (artifact
                            // files are plain ASCII).
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// -- serialize --------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("x")
        );
        assert_eq!(j.req("c"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"n":-7}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 5, "xs": [1,2,3], "f": 1.5}"#).unwrap();
        assert_eq!(j.req("n").as_u64(), Some(5));
        assert_eq!(j.req("xs").u32_vec(), Some(vec![1, 2, 3]));
        assert_eq!(j.req("f").as_u64(), None);
        assert_eq!(j.req("f").as_f64(), Some(1.5));
    }
}
