//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations with mean/median/p99 reporting, plus a
//! `black_box` to defeat const-propagation. Used by `rust/benches/*` —
//! both the per-figure reproduction harnesses and the hot-path
//! microbenches that drive the §Perf iteration loop.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use super::stats::Samples;

pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns == 0.0 { 0.0 } else { 1e9 / self.mean_ns }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:8.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:8.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:8.2} ms", ns / 1e6)
    } else {
        format!("{:8.3} s ", ns / 1e9)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {} /iter  (median {}, p99 {}, min {}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
            self.iters
        )
    }
}

/// Benchmark a closure: warm up for `warmup`, then sample batches until
/// `measure` elapses (at least 10 samples). The closure's return value is
/// black-boxed.
pub fn bench<T>(name: &str, mut f: impl FnMut() -> T) -> BenchResult {
    bench_cfg(name, Duration::from_millis(100), Duration::from_millis(400), &mut f)
}

pub fn bench_cfg<T>(
    name: &str,
    warmup: Duration,
    measure: Duration,
    f: &mut impl FnMut() -> T,
) -> BenchResult {
    // Warmup, and estimate per-iter cost to size batches.
    let wstart = Instant::now();
    let mut wi = 0u64;
    while wstart.elapsed() < warmup || wi < 3 {
        std_black_box(f());
        wi += 1;
    }
    let per_iter = wstart.elapsed().as_nanos() as f64 / wi as f64;
    // Batch so each sample is ~200µs (amortizes timer overhead) but at
    // least 1 iter.
    let batch = ((200_000.0 / per_iter.max(1.0)).ceil() as u64).max(1);

    let mut samples = Samples::new();
    let mut iters = 0u64;
    let mstart = Instant::now();
    while mstart.elapsed() < measure || samples.len() < 10 {
        let t = Instant::now();
        for _ in 0..batch {
            std_black_box(f());
        }
        let elapsed = t.elapsed().as_nanos() as f64 / batch as f64;
        samples.push(elapsed);
        iters += batch;
        if samples.len() > 10_000 {
            break;
        }
    }

    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: samples.mean(),
        median_ns: samples.median(),
        p99_ns: samples.p99(),
        min_ns: samples.min(),
    }
}

/// Print a section header for bench binaries.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Render a results table with an aligned `| col | ... |` layout — the
/// format every `bench_fig*` target uses so paper rows are side-by-side
/// comparable.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:>w$} |", c, w = widths[i]));
        }
        s
    };
    println!("{}", line(headers.iter().map(|s| s.to_string()).collect()));
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    println!("{}", line(sep));
    for row in rows {
        println!("{}", line(row.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench_cfg(
            "noop-add",
            Duration::from_millis(5),
            Duration::from_millis(20),
            &mut || black_box(1u64) + black_box(2u64),
        );
        assert!(r.iters > 100);
        assert!(r.mean_ns > 0.0);
        assert!(r.median_ns <= r.p99_ns * 1.001);
        assert!(r.min_ns <= r.mean_ns * 1.001);
    }

    #[test]
    fn table_renders_without_panic() {
        print_table(
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }
}
