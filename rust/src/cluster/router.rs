//! Request → replica routing policies.
//!
//! The paper's speedup is prefix locality: a follow-up only reuses KV if it
//! lands where the shared base prefix is cached. Across N replicas a naive
//! router destroys exactly that locality — "Serving Heterogeneous LoRA
//! Adapters in Distributed LLM Inference Systems" makes instance-aware
//! routing the scaling lever, and S-LoRA shows multi-adapter serving lives
//! or dies on placement. [`RoutePolicy::PrefixAffinity`] keeps the reuse:
//! the cluster hashes the request's base-aligned chain once (the same
//! replica-independent hashes `kvcache::prefix` computes at admission),
//! scores every replica's committed-hash summary against it, and picks the
//! best match penalized by load; cold prefixes fall back to least-loaded.

use crate::metrics::RoutingMetrics;

/// Pluggable placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas regardless of state (the locality-blind
    /// baseline the scaling figure compares against).
    RoundRobin,
    /// Fewest in-flight requests (waiting + running); ties → lowest index.
    LeastLoaded,
    /// Longest cached base-aligned prefix PLUS resident adapter weights
    /// (both in blocks — one currency, the unified memory budget's),
    /// load-penalized; falls back to least-loaded when no replica holds
    /// anything of value for the request.
    PrefixAffinity,
    /// Adapter-residency-first placement (S-LoRA-style): send a request
    /// where its adapter's weights already live, so each replica converges
    /// on a stable subset of hot adapters instead of every replica paging
    /// every adapter. Cold adapters (and base requests) → least-loaded.
    AdapterAffinity,
}

impl RoutePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::PrefixAffinity => "prefix-affinity",
            RoutePolicy::AdapterAffinity => "adapter-affinity",
        }
    }

    /// Parse a CLI/HTTP policy name.
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "round-robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "least-loaded" | "ll" => Some(RoutePolicy::LeastLoaded),
            "prefix-affinity" | "affinity" => Some(RoutePolicy::PrefixAffinity),
            "adapter-affinity" | "adapter" => Some(RoutePolicy::AdapterAffinity),
            _ => None,
        }
    }
}

/// What the router sees of one replica when placing one request.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    /// In-flight requests (waiting + running).
    pub load: usize,
    /// Leading blocks of the request's hash chain this replica's committed
    /// summary covers (0 when the policy doesn't score affinity).
    pub affinity_blocks: usize,
    /// Weight pages of the request's adapter already resident on this
    /// replica (0 for base requests, non-resident adapters, or when
    /// adapter paging is off — then every replica is equally "resident").
    pub adapter_blocks: usize,
    /// Free device blocks right now — the heterogeneous-fleet term
    /// (DESIGN.md §20): replicas may carry different block budgets, and a
    /// COLD placement seeds a new adapter/prefix footprint, so headroom
    /// matters where affinity offers nothing. Scored only when
    /// [`RouterConfig::free_budget_weight`] is nonzero.
    pub free_blocks: usize,
    /// False for down or draining replicas: every policy must skip them —
    /// a draining replica still finishes its in-flight work but accepts
    /// nothing new, a down replica holds nothing at all.
    pub healthy: bool,
    /// The health monitor holds missed heartbeats against this replica
    /// (DESIGN.md §19). Still routable — suspicion is not death — but
    /// charged [`SUSPECT_LOAD_PENALTY`] virtual load so traffic drifts
    /// away while the monitor decides.
    pub suspected: bool,
    /// Freshly activated replica still warming its gossiped summary:
    /// routed overflow-only — eligible just when every settled healthy
    /// replica already has work in flight.
    pub warming: bool,
}

#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    pub policy: RoutePolicy,
    /// How many cached blocks one queued request is "worth" when trading
    /// affinity against imbalance: effective score = affinity_blocks -
    /// penalty × load. Low values chase cache hits harder; high values
    /// behave closer to least-loaded.
    pub load_penalty_blocks: f64,
    /// Heterogeneous-fleet cold placement (DESIGN.md §20): when an
    /// affinity policy finds no warm replica, score the fallback as
    /// `free_budget_weight × free_blocks − load_penalty_blocks × load`
    /// instead of pure least-loaded, steering new adapter/prefix
    /// footprints toward the replicas with room to keep them resident.
    /// 0.0 (the default) is bit-identical to the least-loaded fallback.
    pub free_budget_weight: f64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            policy: RoutePolicy::PrefixAffinity,
            load_penalty_blocks: 2.0,
            free_budget_weight: 0.0,
        }
    }
}

/// How one placement was decided (PrefixAffinity tags warm vs cold).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// Policy doesn't score affinity (RoundRobin / LeastLoaded).
    Plain,
    /// PrefixAffinity found a warm replica holding `blocks` of the chain.
    Warm { blocks: usize },
    /// PrefixAffinity found no warm replica; least-loaded fallback.
    Cold,
}

/// One placement decision. Counted into the stats only via
/// [`Router::record`], once the submission actually succeeded — rejected
/// requests must not skew the routing counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    pub replica: usize,
    pub kind: PlacementKind,
}

/// Virtual load charged to a monitor-suspected replica: one suspected
/// replica is "worth" this many queued requests when trading affinity
/// against placement risk. Round-robin, which has no load axis, instead
/// skips suspected replicas whenever a trusted one exists.
pub const SUSPECT_LOAD_PENALTY: usize = 8;

#[derive(Debug)]
pub struct Router {
    cfg: RouterConfig,
    rr_next: usize,
    pub stats: RoutingMetrics,
}

fn least_loaded(views: &[ReplicaView]) -> usize {
    views
        .iter()
        .enumerate()
        .filter(|(_, v)| v.healthy)
        .min_by_key(|(_, v)| v.load)
        .map(|(i, _)| i)
        .expect("no healthy replicas")
}

impl Router {
    pub fn new(cfg: RouterConfig, n_replicas: usize) -> Self {
        Router { cfg, rr_next: 0, stats: RoutingMetrics::new(n_replicas) }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.cfg.policy
    }

    /// The load-penalty coefficient (blocks per queued request) — the
    /// cluster's probe watermark needs it to upper-bound a replica's
    /// score before paying for a summary scan.
    pub fn load_penalty(&self) -> f64 {
        self.cfg.load_penalty_blocks
    }

    /// Does this policy need the request's hash chain scored per replica?
    /// (Lets the cluster skip hashing entirely for RR / least-loaded /
    /// adapter-affinity, which never look at the chain.)
    pub fn needs_chain(&self) -> bool {
        self.cfg.policy == RoutePolicy::PrefixAffinity
    }

    /// Pick a replica for one request. Deterministic: ties always resolve
    /// to the lowest index, so runs are reproducible. Unhealthy (down or
    /// draining) replicas are excluded by every policy; the caller must
    /// guarantee at least one healthy view. Does not touch the exported
    /// stats (the round-robin cursor does advance); call
    /// [`Router::record`] after the submission succeeds.
    pub fn choose(&mut self, views: &[ReplicaView]) -> Placement {
        assert!(
            views.iter().any(|v| v.healthy),
            "routing over zero healthy replicas"
        );
        // Self-driving adjustments (DESIGN.md §19). Both are strict
        // no-ops on a settled fleet (no warming, no suspicion), so the
        // pre-§19 placement stream is bit-identical — pinned by tests.
        let adjusted: Option<Vec<ReplicaView>> =
            if views.iter().any(|v| v.healthy && (v.warming || v.suspected)) {
                let mut vs = views.to_vec();
                // Warming replicas take only overflow: while any settled
                // healthy replica sits idle, a cold summary must not win
                // a placement it cannot score honestly.
                if vs.iter().any(|v| v.healthy && !v.warming && v.load == 0) {
                    for v in vs.iter_mut() {
                        if v.warming {
                            v.healthy = false;
                        }
                    }
                }
                // Suspected replicas carry virtual load; round-robin has
                // no load axis, so it skips them when it has a choice.
                let have_trusted = vs.iter().any(|v| v.healthy && !v.suspected);
                for v in vs.iter_mut() {
                    if v.healthy && v.suspected {
                        v.load += SUSPECT_LOAD_PENALTY;
                        if have_trusted && self.cfg.policy == RoutePolicy::RoundRobin {
                            v.healthy = false;
                        }
                    }
                }
                Some(vs)
            } else {
                None
            };
        let views = adjusted.as_deref().unwrap_or(views);
        match self.cfg.policy {
            RoutePolicy::RoundRobin => {
                // Advance the cursor past unhealthy replicas (at most one
                // full lap — at least one view is healthy).
                let i = loop {
                    let i = self.rr_next % views.len();
                    self.rr_next += 1;
                    if views[i].healthy {
                        break i;
                    }
                };
                Placement { replica: i, kind: PlacementKind::Plain }
            }
            RoutePolicy::LeastLoaded => {
                Placement { replica: least_loaded(views), kind: PlacementKind::Plain }
            }
            RoutePolicy::PrefixAffinity => {
                // KV prefix and resident weights trade in one currency —
                // blocks the placement would not have to re-fill/re-load.
                self.affine_choose(views, |v| v.affinity_blocks + v.adapter_blocks)
            }
            RoutePolicy::AdapterAffinity => {
                self.affine_choose(views, |v| v.adapter_blocks)
            }
        }
    }

    /// Shared affinity scaffold: maximize `value(view) - penalty × load`
    /// over the healthy replicas; when no healthy replica holds any value
    /// for the request (or the load penalty steers it off every warm
    /// replica), fall back cold to least-loaded. `Warm.blocks` reports the
    /// value actually landed on.
    fn affine_choose(
        &self,
        views: &[ReplicaView],
        value: impl Fn(&ReplicaView) -> usize,
    ) -> Placement {
        let best = views
            .iter()
            .filter(|v| v.healthy)
            .map(&value)
            .max()
            .unwrap_or(0);
        if best == 0 {
            // Cold: nothing to gain anywhere — balance load, weighing
            // free device budget when configured (heterogeneous fleets).
            return Placement { replica: self.cold_fallback(views), kind: PlacementKind::Cold };
        }
        let score =
            |v: &ReplicaView| value(v) as f64 - self.cfg.load_penalty_blocks * v.load as f64;
        let mut pick = views.iter().position(|v| v.healthy).expect("checked in choose");
        // Hoist the incumbent's score out of the loop: re-scoring
        // `views[pick]` on every comparison doubled the scan's work.
        let mut pick_score = score(&views[pick]);
        for (j, v) in views.iter().enumerate() {
            if v.healthy {
                let sc = score(v);
                if sc > pick_score {
                    pick = j;
                    pick_score = sc;
                }
            }
        }
        let blocks = value(&views[pick]);
        if blocks == 0 {
            // The load penalty steered the request off every warm
            // replica: it lands cold and must be counted as a fallback,
            // not a hit.
            Placement { replica: pick, kind: PlacementKind::Cold }
        } else {
            Placement { replica: pick, kind: PlacementKind::Warm { blocks } }
        }
    }

    /// The cold-placement fallback: pure least-loaded unless
    /// `free_budget_weight` is set, in which case replicas with device
    /// headroom win the tie for a new footprint. Ties resolve to the
    /// lowest index, matching every other policy's determinism contract.
    fn cold_fallback(&self, views: &[ReplicaView]) -> usize {
        if self.cfg.free_budget_weight <= 0.0 {
            return least_loaded(views);
        }
        let score = |v: &ReplicaView| {
            self.cfg.free_budget_weight * v.free_blocks as f64
                - self.cfg.load_penalty_blocks * v.load as f64
        };
        let mut pick = views.iter().position(|v| v.healthy).expect("no healthy replicas");
        let mut pick_score = score(&views[pick]);
        for (j, v) in views.iter().enumerate() {
            if v.healthy {
                let sc = score(v);
                if sc > pick_score {
                    pick = j;
                    pick_score = sc;
                }
            }
        }
        pick
    }

    /// Count a successfully-submitted placement into the routing stats.
    pub fn record(&mut self, p: Placement) {
        self.stats.routed[p.replica] += 1;
        match p.kind {
            PlacementKind::Plain => {}
            PlacementKind::Warm { blocks } => {
                self.stats.affinity_hits += 1;
                self.stats.affinity_blocks_matched += blocks as u64;
            }
            PlacementKind::Cold => self.stats.affinity_fallbacks += 1,
        }
    }

    /// Count a sticky (session-pinned) placement: the policy was bypassed
    /// because the conversation's replica is a construction-time fact.
    pub fn record_sticky(&mut self, replica: usize) {
        self.stats.routed[replica] += 1;
        self.stats.sticky_routed += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(specs: &[(usize, usize)]) -> Vec<ReplicaView> {
        specs
            .iter()
            .map(|&(load, aff)| ReplicaView {
                load,
                affinity_blocks: aff,
                adapter_blocks: 0,
                free_blocks: 0,
                healthy: true,
                suspected: false,
                warming: false,
            })
            .collect()
    }

    /// (load, prefix blocks, resident adapter-weight blocks) triples.
    fn views3(specs: &[(usize, usize, usize)]) -> Vec<ReplicaView> {
        specs
            .iter()
            .map(|&(load, aff, ad)| ReplicaView {
                load,
                affinity_blocks: aff,
                adapter_blocks: ad,
                free_blocks: 0,
                healthy: true,
                suspected: false,
                warming: false,
            })
            .collect()
    }

    fn router(policy: RoutePolicy, n: usize) -> Router {
        Router::new(RouterConfig { policy, ..Default::default() }, n)
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = router(RoutePolicy::RoundRobin, 3);
        let v = views(&[(0, 0), (9, 0), (0, 0)]);
        for want in [0, 1, 2, 0] {
            let p = r.choose(&v);
            assert_eq!(p.replica, want);
            assert_eq!(p.kind, PlacementKind::Plain);
            r.record(p);
        }
        assert_eq!(r.stats.routed, vec![2, 1, 1]);
    }

    #[test]
    fn least_loaded_picks_min_ties_lowest() {
        let mut r = router(RoutePolicy::LeastLoaded, 3);
        assert_eq!(r.choose(&views(&[(4, 0), (1, 0), (2, 0)])).replica, 1);
        assert_eq!(r.choose(&views(&[(3, 0), (3, 0), (3, 0)])).replica, 0);
    }

    #[test]
    fn affinity_prefers_cached_prefix() {
        let mut r = router(RoutePolicy::PrefixAffinity, 3);
        let p = r.choose(&views(&[(0, 0), (0, 6), (0, 2)]));
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Warm { blocks: 6 });
        r.record(p);
        assert_eq!(r.stats.affinity_hits, 1);
        assert_eq!(r.stats.affinity_blocks_matched, 6);
    }

    #[test]
    fn affinity_cold_falls_back_to_least_loaded() {
        let mut r = router(RoutePolicy::PrefixAffinity, 3);
        let p = r.choose(&views(&[(4, 0), (1, 0), (2, 0)]));
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Cold);
        r.record(p);
        assert_eq!(r.stats.affinity_fallbacks, 1);
        assert_eq!(r.stats.affinity_hits, 0);
    }

    #[test]
    fn cold_fallback_weighs_free_budget_on_heterogeneous_fleets() {
        // Equal load, no affinity anywhere: weight 0.0 (the default) must
        // reproduce least-loaded exactly (ties → lowest index), while a
        // positive weight steers the cold footprint to the replica with
        // device headroom. DESIGN.md §20.
        let mut v = views(&[(2, 0), (2, 0), (2, 0)]);
        v[0].free_blocks = 8;
        v[1].free_blocks = 64;
        v[2].free_blocks = 64;

        let mut r = router(RoutePolicy::PrefixAffinity, 3);
        let p = r.choose(&v);
        assert_eq!(p.replica, 0, "weight 0.0 is exactly least-loaded");
        assert_eq!(p.kind, PlacementKind::Cold);

        let mut r = Router::new(
            RouterConfig {
                policy: RoutePolicy::PrefixAffinity,
                free_budget_weight: 0.5,
                ..Default::default()
            },
            3,
        );
        let p = r.choose(&v);
        assert_eq!(p.replica, 1, "headroom wins the cold tie, ties → lowest index");
        assert_eq!(p.kind, PlacementKind::Cold);

        // The weight is traded against load, not absolute: 64 extra free
        // blocks at 0.5/block (= 32) lose to 20 fewer queued requests at
        // the default 2.0 penalty (= 40).
        v[0].load = 2;
        v[1].load = 22;
        v[2].load = 22;
        assert_eq!(r.choose(&v).replica, 0);

        // Warm affinity still short-circuits the fallback entirely.
        v[1].load = 2;
        v[2].load = 2;
        v[0].affinity_blocks = 6;
        let p = r.choose(&v);
        assert_eq!(p.replica, 0);
        assert_eq!(p.kind, PlacementKind::Warm { blocks: 6 });
    }

    #[test]
    fn affinity_trades_against_load() {
        // 4 cached blocks on a replica with 4 queued requests (score
        // 4 - 2.0×4 = -4) loses to an idle replica holding just 1 block
        // (score 1): the load penalty stops convoying onto one replica.
        let mut r = router(RoutePolicy::PrefixAffinity, 2);
        assert_eq!(r.choose(&views(&[(4, 4), (0, 1)])).replica, 1);
    }

    #[test]
    fn overloaded_warm_replica_yields_a_cold_placement() {
        // Warm replica exists (best > 0) but its load penalty loses to an
        // idle zero-affinity replica (3 - 2.0×4 = -5 vs 0): the request
        // lands cold and must be classified — and counted — as such.
        let mut r = router(RoutePolicy::PrefixAffinity, 2);
        let p = r.choose(&views(&[(4, 3), (0, 0)]));
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Cold);
        r.record(p);
        assert_eq!(r.stats.affinity_hits, 0);
        assert_eq!(r.stats.affinity_fallbacks, 1);
    }

    #[test]
    fn prefix_affinity_counts_resident_adapters_as_value() {
        // Replica 1 has no cached prefix but holds the request's adapter
        // weights (32 pages) — that beats replica 0's short 4-block prefix:
        // not reloading weights saves more memory traffic than 4 blocks
        // of KV.
        let mut r = router(RoutePolicy::PrefixAffinity, 2);
        let p = r.choose(&views3(&[(0, 4, 0), (0, 0, 32)]));
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Warm { blocks: 32 });
        // Both terms on one replica add up.
        let p = r.choose(&views3(&[(0, 4, 32), (0, 6, 0)]));
        assert_eq!(p.replica, 0);
        assert_eq!(p.kind, PlacementKind::Warm { blocks: 36 });
    }

    #[test]
    fn adapter_affinity_follows_residency_and_ignores_prefixes() {
        let mut r = router(RoutePolicy::AdapterAffinity, 3);
        // Prefix blocks don't matter; the resident adapter does.
        let p = r.choose(&views3(&[(0, 100, 0), (1, 0, 32), (0, 0, 0)]));
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Warm { blocks: 32 });
        r.record(p);
        assert_eq!(r.stats.affinity_hits, 1);
        // Nothing resident anywhere → least-loaded cold fallback.
        let p = r.choose(&views3(&[(2, 50, 0), (1, 0, 0), (3, 0, 0)]));
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Cold);
        // An overloaded warm replica loses to an idle cold one.
        let p = r.choose(&views3(&[(20, 0, 8), (0, 0, 0)]));
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Cold);
    }

    #[test]
    fn every_policy_skips_unhealthy_replicas() {
        let mark = |mut v: Vec<ReplicaView>, down: &[usize]| {
            for &i in down {
                v[i].healthy = false;
            }
            v
        };
        // RoundRobin: the cursor skips over the down replica entirely.
        let mut r = router(RoutePolicy::RoundRobin, 3);
        let v = mark(views(&[(0, 0), (0, 0), (0, 0)]), &[1]);
        let picks: Vec<usize> = (0..4).map(|_| r.choose(&v).replica).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
        // LeastLoaded: the idle-but-down replica loses to a loaded-but-up
        // one.
        let mut r = router(RoutePolicy::LeastLoaded, 2);
        let v = mark(views(&[(0, 0), (9, 0)]), &[0]);
        assert_eq!(r.choose(&v).replica, 1);
        // PrefixAffinity: a warm-but-down replica yields a cold placement
        // on a healthy one — its cache is unreachable, not merely
        // penalized.
        let mut r = router(RoutePolicy::PrefixAffinity, 2);
        let v = mark(views(&[(0, 8), (0, 0)]), &[0]);
        let p = r.choose(&v);
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Cold);
        // A warm healthy replica still wins over a warmer down one.
        let mut r = router(RoutePolicy::PrefixAffinity, 3);
        let v = mark(views(&[(0, 8), (0, 3), (0, 0)]), &[0]);
        let p = r.choose(&v);
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Warm { blocks: 3 });
        // AdapterAffinity: same rule on the residency term.
        let mut r = router(RoutePolicy::AdapterAffinity, 2);
        let v = mark(views3(&[(0, 0, 32), (5, 0, 8)]), &[0]);
        let p = r.choose(&v);
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Warm { blocks: 8 });
    }

    #[test]
    #[should_panic(expected = "zero healthy")]
    fn choosing_with_no_healthy_replicas_panics() {
        let mut r = router(RoutePolicy::LeastLoaded, 2);
        let mut v = views(&[(0, 0), (0, 0)]);
        v[0].healthy = false;
        v[1].healthy = false;
        let _ = r.choose(&v);
    }

    #[test]
    fn unrecorded_placements_leave_stats_untouched() {
        // The cluster only records after a successful submission; a
        // rejected request must not skew the counters.
        let mut r = router(RoutePolicy::PrefixAffinity, 2);
        let _ = r.choose(&views(&[(0, 3), (0, 0)]));
        assert_eq!(r.stats.total_routed(), 0);
        assert_eq!(r.stats.affinity_hits, 0);
        assert_eq!(r.stats.affinity_fallbacks, 0);
    }

    #[test]
    fn suspected_replicas_are_penalized_not_excluded() {
        // LeastLoaded: a suspected idle replica (0 + 8 virtual) loses to
        // a trusted replica with 5 queued — but still wins against one
        // with 9 queued: penalized, not evacuated.
        let mut r = router(RoutePolicy::LeastLoaded, 2);
        let mut v = views(&[(0, 0), (5, 0)]);
        v[0].suspected = true;
        assert_eq!(r.choose(&v).replica, 1);
        let mut v = views(&[(0, 0), (9, 0)]);
        v[0].suspected = true;
        assert_eq!(r.choose(&v).replica, 0);
        // PrefixAffinity: the suspected warm replica's score drops by
        // penalty × SUSPECT_LOAD_PENALTY (2.0 × 8 = 16 blocks) — an
        // 8-block prefix no longer carries it past a clean cold replica.
        let mut r = router(RoutePolicy::PrefixAffinity, 2);
        let mut v = views(&[(0, 8), (0, 0)]);
        v[0].suspected = true;
        let p = r.choose(&v);
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Cold);
        // ... but a long-enough prefix still wins: suspicion is a
        // penalty, and 40 - 16 = 24 > 0.
        let mut v = views(&[(0, 40), (0, 0)]);
        v[0].suspected = true;
        let p = r.choose(&v);
        assert_eq!(p.replica, 0);
        assert_eq!(p.kind, PlacementKind::Warm { blocks: 40 });
        // RoundRobin: skipped while a trusted replica exists, used when
        // every healthy replica is suspected.
        let mut r = router(RoutePolicy::RoundRobin, 2);
        let mut v = views(&[(0, 0), (0, 0)]);
        v[0].suspected = true;
        let picks: Vec<usize> = (0..3).map(|_| r.choose(&v).replica).collect();
        assert_eq!(picks, vec![1, 1, 1]);
        v[1].suspected = true;
        // All suspected: no trusted alternative, so the cursor (now at
        // index 0 after three skip-advances) proceeds through them.
        assert_eq!(r.choose(&v).replica, 0, "all suspected: cursor proceeds");
    }

    #[test]
    fn warming_replicas_take_only_overflow() {
        // A settled replica is idle: the warming replica is invisible to
        // every policy, even as the least-loaded candidate.
        let mut r = router(RoutePolicy::LeastLoaded, 2);
        let mut v = views(&[(3, 0), (0, 0)]);
        v[1].warming = true;
        assert_eq!(r.choose(&v).replica, 0, "idle settled replica absorbs");
        // Every settled replica is busy: overflow flows to the warming
        // replica (it is the least-loaded healthy candidate now).
        let mut v = views(&[(3, 0), (1, 0)]);
        v[1].warming = true;
        assert_eq!(r.choose(&v).replica, 1, "overflow reaches the cold replica");
        // Same under PrefixAffinity's cold fallback.
        let mut r = router(RoutePolicy::PrefixAffinity, 2);
        let mut v = views(&[(2, 0), (0, 0)]);
        v[1].warming = true;
        let p = r.choose(&v);
        assert_eq!(p.replica, 1);
        assert_eq!(p.kind, PlacementKind::Cold);
        // A fleet that is ALL warming still routes (bootstrap).
        let mut v = views(&[(0, 0), (2, 0)]);
        v[0].warming = true;
        v[1].warming = true;
        assert_eq!(r.choose(&v).replica, 0);
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastLoaded,
            RoutePolicy::PrefixAffinity,
            RoutePolicy::AdapterAffinity,
        ] {
            assert_eq!(RoutePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("adapter"), Some(RoutePolicy::AdapterAffinity));
        assert_eq!(RoutePolicy::parse("nope"), None);
    }
}
