//! Heartbeat-driven replica failure detection (DESIGN.md §19).
//!
//! Every cluster step, each participating replica either delivers a
//! heartbeat or misses one; the [`HealthMonitor`] counts *consecutive*
//! misses per replica and walks the state machine
//!
//! ```text
//!   Up --(suspect_after_misses)--> Suspected(n) --(down_after_misses)--> Down
//!    ^            |
//!    +--resumed beat (Recovered)
//! ```
//!
//! `Suspected` is a routing penalty, not an evacuation: the replica keeps
//! its requests and leases, and a resumed beat restores it with zero
//! loss. `Down` is terminal from the monitor's point of view — the
//! cluster runs the same failover pipeline an operator-declared
//! `POST /cluster/replicas/{i}/fail` would, and only an explicit
//! `restore_replica` re-arms monitoring.
//!
//! The monitor is deliberately dumb and deterministic: pure counters on
//! the shared simulated step clock, no timers, no randomness. Detection
//! latency in steps equals the configured miss threshold *exactly*,
//! which the unit tests pin.

use crate::config::FleetConfig;

/// Monitor-visible state of one replica, derived from its miss count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    Up,
    /// `n` consecutive heartbeats missed (`suspect_after <= n < down_after`).
    Suspected(u32),
    Down,
}

impl HealthState {
    /// The `health_detail` rendering (`up | suspected(n) | down`).
    pub fn detail(&self) -> String {
        match self {
            HealthState::Up => "up".to_string(),
            HealthState::Suspected(n) => format!("suspected({n})"),
            HealthState::Down => "down".to_string(),
        }
    }
}

/// One replica's input to a monitoring round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Beat {
    /// Heartbeat received this step.
    Seen,
    /// Heartbeat expected but absent (silenced or dead replica).
    Missed,
    /// Replica is not participating (operator-down, standby, already
    /// declared down): hold state, count nothing.
    Ignore,
}

/// State-machine edges crossed during one monitoring round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transition {
    /// Crossed the suspect threshold this round.
    Suspected { replica: usize, misses: u32 },
    /// Crossed the down threshold this round: the caller must run its
    /// failover pipeline.
    Down { replica: usize },
    /// A suspected replica resumed beating; miss count cleared.
    Recovered { replica: usize },
}

/// Result of one monitoring round.
#[derive(Debug, Clone, Default)]
pub struct Observation {
    pub transitions: Vec<Transition>,
    /// Heartbeats missed this round (feeds the
    /// `alora_serve_heartbeat_misses_total` counter).
    pub misses: u32,
}

#[derive(Debug, Clone)]
pub struct HealthMonitor {
    suspect_after: u32,
    down_after: u32,
    /// Consecutive misses per replica; saturates at `down_after` (a dead
    /// replica's counter must not wrap or grow unbounded).
    misses: Vec<u32>,
}

impl HealthMonitor {
    pub fn new(n_replicas: usize, fleet: &FleetConfig) -> Self {
        assert!(
            fleet.down_after_misses > fleet.suspect_after_misses
                && fleet.suspect_after_misses > 0,
            "fleet config not validated"
        );
        HealthMonitor {
            suspect_after: fleet.suspect_after_misses,
            down_after: fleet.down_after_misses,
            misses: vec![0; n_replicas],
        }
    }

    /// One monitoring round over the per-replica beats. Deterministic:
    /// transitions are emitted in replica order.
    pub fn observe(&mut self, beats: &[Beat]) -> Observation {
        assert_eq!(beats.len(), self.misses.len(), "beat vector sized to fleet");
        let mut obs = Observation::default();
        for (i, beat) in beats.iter().enumerate() {
            match beat {
                Beat::Ignore => {}
                Beat::Seen => {
                    if (self.suspect_after..self.down_after).contains(&self.misses[i]) {
                        obs.transitions.push(Transition::Recovered { replica: i });
                    }
                    // A Down counter stays pinned: only an explicit
                    // `reset` (restore_replica) re-arms a declared death.
                    if self.misses[i] < self.down_after {
                        self.misses[i] = 0;
                    }
                }
                Beat::Missed => {
                    if self.misses[i] >= self.down_after {
                        continue; // already declared; nothing new to say
                    }
                    self.misses[i] += 1;
                    obs.misses += 1;
                    if self.misses[i] == self.suspect_after {
                        obs.transitions.push(Transition::Suspected {
                            replica: i,
                            misses: self.misses[i],
                        });
                    } else if self.misses[i] == self.down_after {
                        obs.transitions.push(Transition::Down { replica: i });
                    }
                }
            }
        }
        obs
    }

    pub fn state(&self, i: usize) -> HealthState {
        let m = self.misses[i];
        if m >= self.down_after {
            HealthState::Down
        } else if m >= self.suspect_after {
            HealthState::Suspected(m)
        } else {
            HealthState::Up
        }
    }

    /// Consecutive misses currently held against replica `i`.
    pub fn misses(&self, i: usize) -> u32 {
        self.misses[i]
    }

    /// Re-arm monitoring for a restored / freshly activated replica.
    pub fn reset(&mut self, i: usize) {
        self.misses[i] = 0;
    }

    /// Record an operator-declared death so the monitor agrees with the
    /// cluster's health table (and never re-fires Down for this replica).
    pub fn mark_down(&mut self, i: usize) {
        self.misses[i] = self.down_after;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(suspect: u32, down: u32) -> FleetConfig {
        FleetConfig {
            suspect_after_misses: suspect,
            down_after_misses: down,
            ..FleetConfig::default()
        }
    }

    #[test]
    fn detection_latency_is_exactly_the_miss_threshold() {
        // Acceptance criterion: a silenced replica is declared Down after
        // exactly `down_after_misses` monitoring rounds — not one early,
        // not one late. Count the rounds like an op counter.
        let f = fleet(3, 6);
        let mut m = HealthMonitor::new(2, &f);
        let mut rounds_to_down = 0u32;
        let mut suspected_at = None;
        for round in 1..=10u32 {
            let obs = m.observe(&[Beat::Missed, Beat::Seen]);
            for t in &obs.transitions {
                match t {
                    Transition::Suspected { replica, misses } => {
                        assert_eq!(*replica, 0);
                        assert_eq!(*misses, 3);
                        suspected_at = Some(round);
                    }
                    Transition::Down { replica } => {
                        assert_eq!(*replica, 0);
                        assert_eq!(rounds_to_down, 0, "Down fires once");
                        rounds_to_down = round;
                    }
                    Transition::Recovered { .. } => panic!("no recovery here"),
                }
            }
        }
        assert_eq!(suspected_at, Some(3), "suspected at exactly suspect_after");
        assert_eq!(rounds_to_down, 6, "down at exactly down_after");
        assert_eq!(m.state(0), HealthState::Down);
        assert_eq!(m.state(1), HealthState::Up);
    }

    #[test]
    fn resumed_beats_recover_a_suspected_replica() {
        let f = fleet(2, 5);
        let mut m = HealthMonitor::new(1, &f);
        m.observe(&[Beat::Missed]);
        let obs = m.observe(&[Beat::Missed]);
        assert!(matches!(
            obs.transitions[..],
            [Transition::Suspected { replica: 0, misses: 2 }]
        ));
        assert_eq!(m.state(0), HealthState::Suspected(2));
        // Beat resumes: Recovered edge, counter cleared, back to Up.
        let obs = m.observe(&[Beat::Seen]);
        assert!(matches!(obs.transitions[..], [Transition::Recovered { replica: 0 }]));
        assert_eq!(m.state(0), HealthState::Up);
        assert_eq!(m.misses(0), 0);
        // The next miss starts the count from scratch.
        let obs = m.observe(&[Beat::Missed]);
        assert!(obs.transitions.is_empty());
        assert_eq!(m.state(0), HealthState::Up);
    }

    #[test]
    fn down_is_terminal_until_reset() {
        let f = fleet(1, 2);
        let mut m = HealthMonitor::new(1, &f);
        m.observe(&[Beat::Missed]);
        m.observe(&[Beat::Missed]);
        assert_eq!(m.state(0), HealthState::Down);
        // Neither further misses nor a late beat move a Down replica.
        let obs = m.observe(&[Beat::Missed]);
        assert!(obs.transitions.is_empty());
        assert_eq!(obs.misses, 0, "declared replicas stop accruing misses");
        let obs = m.observe(&[Beat::Seen]);
        assert!(obs.transitions.is_empty());
        assert_eq!(m.state(0), HealthState::Down);
        // Only an explicit restore re-arms.
        m.reset(0);
        assert_eq!(m.state(0), HealthState::Up);
    }

    #[test]
    fn ignored_replicas_hold_state_and_count_nothing() {
        let f = fleet(2, 4);
        let mut m = HealthMonitor::new(1, &f);
        m.observe(&[Beat::Missed]);
        for _ in 0..10 {
            let obs = m.observe(&[Beat::Ignore]);
            assert!(obs.transitions.is_empty());
            assert_eq!(obs.misses, 0);
        }
        assert_eq!(m.misses(0), 1, "Ignore froze the counter");
    }

    #[test]
    fn miss_counter_feeds_the_metrics_surface() {
        let f = fleet(2, 4);
        let mut m = HealthMonitor::new(3, &f);
        let obs = m.observe(&[Beat::Missed, Beat::Missed, Beat::Seen]);
        assert_eq!(obs.misses, 2);
        m.mark_down(0);
        assert_eq!(m.state(0), HealthState::Down);
    }
}
