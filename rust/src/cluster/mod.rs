//! Horizontal scale-out: N engine replicas behind a cache-affinity router.
//!
//! A [`Cluster`] owns N independent [`Engine`] replicas and implements the
//! same [`EngineDriver`] interface a single engine does, so the
//! coordinator, the pipeline drivers and the HTTP server drive a fleet
//! without knowing it. Placement is the [`Router`]'s job; the interesting
//! policy is [`RoutePolicy::PrefixAffinity`]: it computes the request's
//! base-aligned block-hash chain once (the identical replica-independent
//! hashes admission uses, `kvcache::prefix`), scores each replica's
//! committed-hash summary ([`crate::kvcache::summary::HashSummary`], fed
//! by commit/eviction events) against that chain, and places the request
//! where its prefix is already resident — so the paper's cross-model KV
//! reuse survives scale-out. Conversation follow-ups submitted by the
//! coordinator inherit their parent's replica automatically: the child's
//! chain extends the parent's, and only the parent's replica scores > 0.
//!
//! Virtual time: replicas run in parallel, so the cluster clock is the max
//! over replica clocks (fleet makespan). Stepping advances every replica
//! with work by one batch; an idle replica's clock is synced forward when
//! a request is routed to it (it genuinely sat idle that long).
//!
//! Request ids are fleet-unique by construction: replica i issues ids
//! `i, i+n, i+2n, ...` (see [`Engine::set_id_namespace`]), so finished
//! outputs flow back through the uniform interface untranslated.
//!
//! Replicas are not assumed immortal: [`Cluster::fail_replica`] /
//! [`Cluster::drain_replica`] / [`Cluster::restore_replica`] move them
//! through [`ReplicaHealth`] states. The router excludes everything but
//! `Up`; failing a replica evacuates its queued work and requeues it
//! onto survivors under the SAME ids (continuation priority) while its
//! leases orphan and its cache is wiped (restore = cold start). The
//! [`FailoverReport`] hands the serving layer what it needs to repair
//! affected sessions (DESIGN.md §15).

pub mod router;

pub use router::{Placement, PlacementKind, ReplicaView, RoutePolicy, Router, RouterConfig};

use crate::adapter::AdapterRegistry;
use crate::config::EngineConfig;
use crate::engine::{Engine, EngineDriver, EvacuatedRequest, Executor};
use crate::kvcache::block::BlockHash;
use crate::kvcache::chain::ChainRef;
use crate::kvcache::prefix::{block_hashes, HashContext};
use crate::metrics::{Metrics, RoutingMetrics};
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams, TurnEvent};
use crate::simulator::CostModel;
use crate::util::fxmap::FxHashMap;
use crate::util::json::Json;

/// One replica's serving state. Routing excludes everything but `Up`;
/// the difference between the other two is what happens to work already
/// on the replica: `Draining` finishes it (planned maintenance), `Down`
/// lost it (the failover path evacuated and requeued it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaHealth {
    Up,
    Draining,
    Down,
}

impl ReplicaHealth {
    pub fn name(&self) -> &'static str {
        match self {
            ReplicaHealth::Up => "up",
            ReplicaHealth::Draining => "draining",
            ReplicaHealth::Down => "down",
        }
    }
}

/// What one `fail_replica` did — the serving layer feeds this to
/// [`crate::session::SessionManager::repair_after_failover`] so sessions
/// whose state died with the replica recover transparently.
#[derive(Debug, Clone)]
pub struct FailoverReport {
    pub replica: usize,
    pub num_replicas: usize,
    /// Requests requeued onto survivors (same fleet-unique ids).
    pub requeued: usize,
    /// Lease keys (session ids) whose pinned prefix died with the replica.
    pub orphaned_leases: Vec<u64>,
    /// Evacuated requests no survivor would accept — dropped; they will
    /// never produce an output, so their sessions' turns must be aborted.
    pub rejected: Vec<RequestId>,
    /// Ids that moved to a survivor (subset bookkeeping for `strands`).
    pub relocated: Vec<RequestId>,
}

impl FailoverReport {
    /// Did this request's home — its output, its committed blocks — die
    /// with the failed replica? True for ids constructed on the victim
    /// and not relocated by THIS failover. (An id re-homed by an earlier
    /// failover can answer true conservatively; the only cost is one
    /// policy-routed — i.e. cold-capable — turn.)
    pub fn strands(&self, id: RequestId) -> bool {
        (id.0 % self.num_replicas as u64) as usize == self.replica
            && !self.relocated.contains(&id)
    }
}

/// Cap on remembered failover re-homes. The map cannot be pruned
/// precisely (a session's stickiness peer may be consulted long after
/// its output drained), so it is bounded FIFO instead: past the cap the
/// OLDEST re-home is forgotten and that id resolves back to its `id % n`
/// partition — for stickiness the health check degrades that to one
/// policy-routed (possibly cold) turn. Re-relocation refreshes an id's
/// age, so forgetting a STILL-RUNNING request's re-home would take 4096
/// newer requeues landing within its lifetime. Refreshing is O(1): the
/// id re-enters the order queue under a fresh epoch stamp and its old
/// entry stays behind as a tombstone, skipped (not acted on) when it
/// reaches the front — a tombstone transiently dilutes the effective
/// capacity by one slot until it drains, which only trims the grace
/// window, never evicts out of order.
const MAX_RELOCATIONS: usize = 4096;

pub struct Cluster<E: Executor> {
    replicas: Vec<Engine<E>>,
    router: Router,
    /// Per-replica serving state; routing only sees `Up` replicas.
    health: Vec<ReplicaHealth>,
    /// Failover re-homes: request id → (replica it was requeued onto,
    /// epoch of that re-home). Overrides the construction-time `id % n`
    /// mapping for stickiness, leases, and event routing. Bounded by
    /// [`MAX_RELOCATIONS`] (FIFO, `relocation_order`); the epoch lets
    /// eviction tell a live entry from a tombstone left by re-relocation.
    relocated: FxHashMap<RequestId, (usize, u64)>,
    /// Insertion order of `relocated` entries, stamped with the epoch of
    /// the insertion (front = oldest = first forgotten past the cap; an
    /// entry whose stamp no longer matches the map's is a tombstone and
    /// is skipped).
    relocation_order: std::collections::VecDeque<(RequestId, u64)>,
    /// Monotone stamp source for `relocation_order` entries.
    relocation_epoch: u64,
    /// Fleet-level registry: the coordinator's per-stage series land here;
    /// `/metrics` renders this merged with every replica's counters.
    metrics: Metrics,
}

/// One replica's headline numbers for `GET /cluster`.
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub replica: usize,
    /// Serving state: "up", "draining", or "down".
    pub health: &'static str,
    pub clock: f64,
    pub running: usize,
    pub waiting: usize,
    pub finished: u64,
    pub free_blocks: u32,
    pub total_blocks: u32,
    /// Committed (routable) blocks in this replica's summary.
    pub committed_blocks: u64,
    pub hit_rate: f64,
    pub routed: u64,
    /// Adapter ids resident on this replica (ascending; empty with
    /// adapter paging off — everything is implicitly resident then).
    pub resident_adapters: Vec<u32>,
    /// Blocks charged to those adapters' weights.
    pub adapter_resident_blocks: usize,
    pub adapter_loads: u64,
    pub adapter_evictions: u64,
}

/// The per-replica engine configuration summary `GET /cluster` reports so
/// fleet dashboards don't need out-of-band config (replicas are identical
/// by construction, so one summary describes them all).
#[derive(Debug, Clone)]
pub struct ReplicaConfigSummary {
    pub model: String,
    pub block_size: u32,
    /// Device budget per replica in blocks (KV + adapter weights).
    pub total_blocks: u64,
    pub max_batch_tokens: u32,
    pub max_num_seqs: u32,
    pub admission_watermark: f64,
    pub base_aligned_hashing: bool,
    pub adapter_paging: bool,
}

/// Fleet snapshot for `GET /cluster` and tests.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    /// Active router policy name.
    pub policy: &'static str,
    pub config: ReplicaConfigSummary,
    pub replicas: Vec<ReplicaStats>,
    pub routing: RoutingMetrics,
    /// Token-weighted prefix hit rate across the fleet.
    pub aggregate_hit_rate: f64,
    /// Fleet fraction of adapter admissions that found weights resident.
    pub aggregate_adapter_hit_rate: f64,
}

impl ClusterStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::str(self.policy)),
            (
                "config",
                Json::obj(vec![
                    ("model", Json::str(self.config.model.clone())),
                    ("block_size", Json::num(self.config.block_size as f64)),
                    ("total_blocks", Json::num(self.config.total_blocks as f64)),
                    ("max_batch_tokens", Json::num(self.config.max_batch_tokens as f64)),
                    ("max_num_seqs", Json::num(self.config.max_num_seqs as f64)),
                    (
                        "admission_watermark",
                        Json::num(self.config.admission_watermark),
                    ),
                    (
                        "base_aligned_hashing",
                        Json::Bool(self.config.base_aligned_hashing),
                    ),
                    ("adapter_paging", Json::Bool(self.config.adapter_paging)),
                ]),
            ),
            ("aggregate_hit_rate", Json::num(self.aggregate_hit_rate)),
            (
                "aggregate_adapter_hit_rate",
                Json::num(self.aggregate_adapter_hit_rate),
            ),
            (
                "routing",
                Json::obj(vec![
                    (
                        "routed",
                        Json::Arr(
                            self.routing.routed.iter().map(|&n| Json::num(n as f64)).collect(),
                        ),
                    ),
                    ("affinity_hits", Json::num(self.routing.affinity_hits as f64)),
                    ("affinity_fallbacks", Json::num(self.routing.affinity_fallbacks as f64)),
                    ("sticky_routed", Json::num(self.routing.sticky_routed as f64)),
                    ("replica_failures", Json::num(self.routing.replica_failures as f64)),
                    ("requeued_requests", Json::num(self.routing.requeued_requests as f64)),
                    ("orphaned_leases", Json::num(self.routing.orphaned_leases as f64)),
                    ("resticks", Json::num(self.routing.resticks as f64)),
                    ("migrations", Json::num(self.routing.migrations as f64)),
                    ("migrated_blocks", Json::num(self.routing.migrated_blocks as f64)),
                    (
                        "migration_recompute_fallbacks",
                        Json::num(self.routing.migration_recompute_fallbacks as f64),
                    ),
                    ("session_forks", Json::num(self.routing.session_forks as f64)),
                    ("imbalance", Json::num(self.routing.imbalance())),
                ]),
            ),
            (
                "replicas",
                Json::Arr(
                    self.replicas
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("replica", Json::num(r.replica as f64)),
                                ("health", Json::str(r.health)),
                                ("clock_s", Json::num(r.clock)),
                                ("running", Json::num(r.running as f64)),
                                ("waiting", Json::num(r.waiting as f64)),
                                ("finished", Json::num(r.finished as f64)),
                                ("free_blocks", Json::num(r.free_blocks as f64)),
                                ("total_blocks", Json::num(r.total_blocks as f64)),
                                ("committed_blocks", Json::num(r.committed_blocks as f64)),
                                ("cache_hit_rate", Json::num(r.hit_rate)),
                                ("routed", Json::num(r.routed as f64)),
                                (
                                    "resident_adapters",
                                    Json::Arr(
                                        r.resident_adapters
                                            .iter()
                                            .map(|&a| Json::num(a as f64))
                                            .collect(),
                                    ),
                                ),
                                (
                                    "adapter_resident_blocks",
                                    Json::num(r.adapter_resident_blocks as f64),
                                ),
                                ("adapter_loads", Json::num(r.adapter_loads as f64)),
                                (
                                    "adapter_evictions",
                                    Json::num(r.adapter_evictions as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl<E: Executor> Cluster<E> {
    /// Wrap pre-built replicas. They must share cache geometry (the
    /// affinity chain is hashed once with one block size) and must not
    /// have served traffic yet (id namespacing).
    pub fn new(replicas: Vec<Engine<E>>, policy: RoutePolicy) -> anyhow::Result<Self> {
        Self::with_config(replicas, RouterConfig { policy, ..Default::default() })
    }

    pub fn with_config(
        mut replicas: Vec<Engine<E>>,
        rcfg: RouterConfig,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!replicas.is_empty(), "cluster needs at least one replica");
        let n = replicas.len();
        // Routing hashes the chain once with replica 0's config/registry
        // and config()/registry() report replica 0's — so replicas must
        // genuinely be identical, not merely block-size-compatible
        // (a base_aligned_hashing or adapter mismatch would silently
        // zero the affinity scores on the divergent replicas).
        for (i, r) in replicas.iter().enumerate() {
            anyhow::ensure!(
                r.is_fresh(),
                "replica {i} has already served traffic (clusters wrap fresh engines)"
            );
            anyhow::ensure!(
                r.cfg == replicas[0].cfg,
                "replica {i} config differs from replica 0"
            );
            anyhow::ensure!(
                r.registry.iter().eq(replicas[0].registry.iter()),
                "replica {i} adapter registry differs from replica 0"
            );
        }
        for (i, r) in replicas.iter_mut().enumerate() {
            r.set_id_namespace(i as u64, n as u64);
        }
        let router = Router::new(rcfg, n);
        Ok(Cluster {
            replicas,
            router,
            health: vec![ReplicaHealth::Up; n],
            relocated: FxHashMap::default(),
            relocation_order: std::collections::VecDeque::new(),
            relocation_epoch: 0,
            metrics: Metrics::new(),
        })
    }

    /// Build `n` identical replicas from a factory.
    pub fn from_factory(
        n: usize,
        policy: RoutePolicy,
        mut f: impl FnMut(usize) -> Engine<E>,
    ) -> anyhow::Result<Self> {
        Self::new((0..n).map(&mut f).collect(), policy)
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn replica(&self, i: usize) -> &Engine<E> {
        &self.replicas[i]
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn health(&self, i: usize) -> ReplicaHealth {
        self.health[i]
    }

    /// Replicas accepting new placements.
    pub fn num_healthy(&self) -> usize {
        self.health.iter().filter(|h| **h == ReplicaHealth::Up).count()
    }

    /// The replica holding `id`'s state: its failover re-home if it was
    /// requeued, else the construction-time partition (`id % n`).
    fn replica_of(&self, id: RequestId) -> usize {
        self.relocated
            .get(&id)
            .map(|&(ri, _)| ri)
            .unwrap_or((id.0 % self.replicas.len() as u64) as usize)
    }

    /// Mark replica `i` failed: its queued work is evacuated and requeued
    /// onto healthy survivors (same fleet-unique ids, continuation
    /// priority — callers blocked on a `RequestId` still get their
    /// output), its leases are orphaned, and its cache is wiped (a later
    /// [`Self::restore_replica`] starts cold). Finished-but-undrained
    /// outputs survive: the completion ledger is serving-layer state, not
    /// device memory. Refuses to take down the last healthy replica —
    /// there would be no survivor to requeue onto.
    pub fn fail_replica(&mut self, i: usize) -> anyhow::Result<FailoverReport> {
        anyhow::ensure!(i < self.replicas.len(), "no replica {i}");
        anyhow::ensure!(
            self.health[i] != ReplicaHealth::Down,
            "replica {i} is already down"
        );
        let survivors = (0..self.replicas.len())
            .filter(|&j| j != i && self.health[j] == ReplicaHealth::Up)
            .count();
        anyhow::ensure!(
            survivors > 0,
            "cannot fail replica {i}: no healthy survivor to requeue onto"
        );
        self.health[i] = ReplicaHealth::Down;
        self.router.stats.replica_failures += 1;
        let evacuated = self.replicas[i].evacuate_requests();
        let orphaned_leases = self.replicas[i].fail_storage();
        self.router.stats.orphaned_leases += orphaned_leases.len() as u64;
        let mut report = FailoverReport {
            replica: i,
            num_replicas: self.replicas.len(),
            requeued: 0,
            orphaned_leases,
            rejected: Vec::new(),
            relocated: Vec::new(),
        };
        // Reverse order: requeued requests enqueue with continuation
        // priority (push-front), so per survivor the LAST submission ends
        // up first — reversing the FCFS evacuation order here restores it
        // on every survivor's queue.
        for ev in evacuated.into_iter().rev() {
            let id = ev.id;
            match self.requeue(ev) {
                Ok(ri) => {
                    report.requeued += 1;
                    report.relocated.push(id);
                    self.note_relocation(id, ri);
                }
                Err(ev) => {
                    // Nobody took it: the request is lost — but it WAS
                    // received, so re-credit the victim's rolled-back
                    // counters (evacuation assumed a survivor would
                    // re-count them) to keep the fleet aggregate at
                    // exactly one per request.
                    let r = &mut self.replicas[i];
                    r.metrics.requests_received += 1;
                    r.metrics.prompt_tokens += ev.prompt.len() as u64;
                    report.rejected.push(id);
                }
            }
        }
        Ok(report)
    }

    /// Record a failover re-home, evicting the oldest LIVE entry past the
    /// cap (see [`MAX_RELOCATIONS`] for the degradation semantics). A
    /// re-relocated id (its survivor failed too) re-enters the order at
    /// the BACK under a fresh epoch stamp — its freshest re-home is also
    /// its freshest fact, and must not be the first forgotten. The stale
    /// front entry becomes a tombstone (its stamp no longer matches the
    /// map's) and is skipped at eviction time, so re-relocation is O(1)
    /// instead of an O(n) scan of the order queue — under a mass requeue
    /// (a replica failing with thousands of re-homed requests aboard,
    /// every one of them re-relocating) the old `retain` walk made each
    /// re-home cost the whole window.
    fn note_relocation(&mut self, id: RequestId, ri: usize) {
        self.relocation_epoch += 1;
        let epoch = self.relocation_epoch;
        self.relocated.insert(id, (ri, epoch));
        self.relocation_order.push_back((id, epoch));
        while self.relocation_order.len() > MAX_RELOCATIONS {
            if let Some((old, stamp)) = self.relocation_order.pop_front() {
                let live =
                    self.relocated.get(&old).map(|&(_, cur)| cur == stamp).unwrap_or(false);
                if live {
                    self.relocated.remove(&old);
                }
            }
        }
    }

    /// Route one evacuated request onto a healthy survivor, trying the
    /// router's pick first and every other healthy replica after it (an
    /// identically-configured survivor re-accepts anything it admitted
    /// before, so fallbacks only matter for exotic third-party states).
    /// Err returns the request when nobody took it (the caller reports
    /// it rejected and re-credits the victim's counters).
    fn requeue(&mut self, ev: EvacuatedRequest) -> Result<usize, EvacuatedRequest> {
        let (views, chain) = self.views_for(ev.target, &ev.prompt, ev.cache_salt);
        let placement = self.router.choose(&views);
        let now = self.clock();
        let mut order = vec![placement.replica];
        order.extend(
            (0..self.replicas.len())
                .filter(|&j| j != placement.replica && self.health[j] == ReplicaHealth::Up),
        );
        for (attempt, &ri) in order.iter().enumerate() {
            let r = &mut self.replicas[ri];
            if !r.has_work() && r.clock() < now {
                r.advance_clock_to(now);
            }
            if r.submit_evacuated(ev.clone(), chain.clone()).is_ok() {
                if attempt == 0 {
                    self.router.record(placement);
                } else {
                    self.router.stats.routed[ri] += 1;
                }
                self.router.stats.requeued_requests += 1;
                return Ok(ri);
            }
        }
        Err(ev)
    }

    /// Begin draining replica `i`: the router stops placing new work on
    /// it (sticky turns re-stick through the policy) while its in-flight
    /// and waiting work runs to completion — planned maintenance, nothing
    /// is lost. Refuses to drain the last healthy replica.
    pub fn drain_replica(&mut self, i: usize) -> anyhow::Result<()> {
        anyhow::ensure!(i < self.replicas.len(), "no replica {i}");
        anyhow::ensure!(
            self.health[i] == ReplicaHealth::Up,
            "replica {i} is {} (only an up replica can drain)",
            self.health[i].name()
        );
        anyhow::ensure!(
            self.num_healthy() > 1,
            "cannot drain replica {i}: it is the last healthy replica"
        );
        self.health[i] = ReplicaHealth::Draining;
        Ok(())
    }

    /// Bring replica `i` back into rotation. A previously failed replica
    /// returns cold (its cache was wiped at failure); a drained one
    /// returns exactly as it was.
    pub fn restore_replica(&mut self, i: usize) -> anyhow::Result<()> {
        anyhow::ensure!(i < self.replicas.len(), "no replica {i}");
        anyhow::ensure!(
            self.health[i] != ReplicaHealth::Up,
            "replica {i} is already up"
        );
        self.health[i] = ReplicaHealth::Up;
        Ok(())
    }

    /// Token-weighted prefix hit rate across the fleet (sums the per-
    /// replica admission counters, so replicas with more traffic weigh
    /// more — the scaling figure's y-axis).
    pub fn aggregate_hit_rate(&self) -> f64 {
        let (mut hit, mut asked) = (0u64, 0u64);
        for r in &self.replicas {
            let s = r.kv_stats();
            hit += s.prefix_tokens_hit;
            asked += s.prefix_tokens_queried;
        }
        if asked == 0 {
            0.0
        } else {
            hit as f64 / asked as f64
        }
    }

    /// Full fleet metrics aggregation — counters summed, latency series
    /// and histograms sample-merged, clock = makespan — for offline
    /// analysis (the scaling figure's fleet latency column). The
    /// `/metrics` scrape path deliberately does NOT use this: merging the
    /// sample vectors is O(requests served).
    pub fn aggregate_metrics(&self) -> Metrics {
        let mut agg = Metrics::new();
        agg.absorb(&self.metrics);
        for r in &self.replicas {
            agg.absorb(&r.metrics);
        }
        agg
    }

    /// Total tokens processed (prompt + generated) across the fleet —
    /// numerator of aggregate throughput over the makespan clock.
    pub fn total_tokens_processed(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.metrics.prompt_tokens + r.metrics.generated_tokens)
            .sum()
    }

    /// Fleet fraction of adapter admissions whose weights were already
    /// resident — what adapter-aware placement optimizes for.
    pub fn aggregate_adapter_hit_rate(&self) -> f64 {
        let (mut hits, mut total) = (0u64, 0u64);
        for r in &self.replicas {
            let s = r.residency().stats();
            hits += s.adapter_admission_hits;
            total += s.adapter_admissions;
        }
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    pub fn stats(&self) -> ClusterStats {
        ClusterStats {
            policy: self.router.policy().name(),
            config: config_summary(&self.replicas[0].cfg),
            replicas: self
                .replicas
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    replica_stats(i, r, self.router.stats.routed[i], self.health[i].name())
                })
                .collect(),
            routing: self.router.stats.clone(),
            aggregate_hit_rate: self.aggregate_hit_rate(),
            aggregate_adapter_hit_rate: self.aggregate_adapter_hit_rate(),
        }
    }

    /// The salting context a request will hash under — the SAME derivation
    /// `Engine::submit_salted` uses (`AdapterRegistry::request_hash_context`),
    /// so the routing chain is byte-identical to the chain admission will
    /// present. Unknown adapters fall back to the base context; submission
    /// rejects them right after (and the placement goes unrecorded).
    fn routing_context(
        &self,
        target: ModelTarget,
        prompt: &[u32],
        cache_salt: u64,
    ) -> HashContext {
        self.replicas[0]
            .registry
            .request_hash_context(
                target.adapter(),
                prompt,
                self.replicas[0].cfg.cache.base_aligned_hashing,
                cache_salt,
            )
            .map(|(_, ctx)| ctx)
            .unwrap_or_else(|| HashContext { cache_salt, ..HashContext::base() })
    }

    /// Score every replica for one request. The chain is hashed ONCE —
    /// each replica contributes only a summary probe plus an O(1)
    /// residency lookup (no pool walks) — and returned as an interned
    /// [`ChainRef`] so submission can pre-seed the request with it
    /// (admission then skips rehashing the same prompt, and handing the
    /// handle to a replica shares arena nodes instead of copying).
    fn views_for(
        &self,
        target: ModelTarget,
        prompt: &[u32],
        cache_salt: u64,
    ) -> (Vec<ReplicaView>, ChainRef) {
        let chain = if self.router.needs_chain() {
            let ctx = self.routing_context(target, prompt, cache_salt);
            let bs = self.replicas[0].cfg.cache.block_size as usize;
            ChainRef::from_hashes(&block_hashes(prompt, bs, &ctx))
        } else {
            ChainRef::empty()
        };
        let views = self.views_for_chain(target, &chain, None);
        (views, chain)
    }

    /// Score every replica against a pre-hashed chain, cheaply:
    ///
    /// - **Lease hint** — if `lease` names a prefix lease a replica pins,
    ///   that replica's summary maintains the chain's matched run
    ///   incrementally (see `HashSummary::track`), so its affinity is
    ///   read in O(1) (plus a probe per delta block past the tracked
    ///   chain) instead of scanning. The hint is validated in O(delta):
    ///   chains are interned in one arena, so "the tracked chain IS a
    ///   prefix of the query chain" is a parent walk to the tracked
    ///   head plus a node-identity compare — no hash comparison and no
    ///   materialization.
    /// - **Probe watermark** — replicas whose best possible score
    ///   (`chain.len() + adapter_blocks - penalty × load`) cannot beat
    ///   the best score already seen are reported with affinity 0 and
    ///   never probed. The router's decision is provably unchanged: the
    ///   true argmax replica is always probed (its true score exceeds
    ///   the watermark that would have skipped it), skipped replicas'
    ///   reported scores never exceed an earlier probed one (so neither
    ///   the argmax nor its first-index tie-break can flip), and the
    ///   all-reported-zero cold corner falls back to least-loaded, which
    ///   the skip condition guarantees is the same replica the full scan
    ///   would have picked. Unhealthy replicas are never probed at all —
    ///   every policy ignores their affinity.
    fn views_for_chain(
        &self,
        target: ModelTarget,
        chain: &ChainRef,
        lease: Option<u64>,
    ) -> Vec<ReplicaView> {
        let penalty = self.router.load_penalty();
        let mut best = f64::NEG_INFINITY;
        // A cold scan (no usable lease hint on that replica) walks the
        // chain front-to-back, which needs a materialized slice. It is
        // built at most ONCE per placement, lazily — a sticky-warm fleet
        // where every probed replica rides the tracked-chain fast path
        // never pays the copy, and delta turns never reach here at all
        // (they take the sticky no-scan path in `submit_sticky_prehashed`).
        let mut full: Option<Vec<BlockHash>> = None;
        let mut views = Vec::with_capacity(self.replicas.len());
        for (i, r) in self.replicas.iter().enumerate() {
            let load = r.num_running() + r.num_waiting();
            // Adapter-residency term: weight pages this replica would
            // NOT have to load for the request (0 with paging off —
            // then weights are free everywhere and the term vanishes).
            let adapter_blocks = target
                .adapter()
                .map(|aid| r.adapter_affinity_blocks(aid))
                .unwrap_or(0);
            let healthy = self.health[i] == ReplicaHealth::Up;
            let affinity_blocks = if chain.is_empty() || !healthy {
                0
            } else {
                let ub = (chain.len() + adapter_blocks) as f64 - penalty * load as f64;
                if ub <= best {
                    0 // cannot win: skip the probe, report no affinity
                } else {
                    let summary = r.routing_summary();
                    let tracked = lease.and_then(|key| {
                        let (matched, len) = summary.tracked_prefix(key)?;
                        let tc = summary.tracked_chain_ref(key)?;
                        // Interned-node identity: the query extends the
                        // tracked chain iff walking back (len − tc.len)
                        // parents lands on tc's head node. O(delta).
                        let valid = len > 0 && chain.is_extension_of(tc);
                        if !valid {
                            return None;
                        }
                        Some(if matched < len {
                            // First miss inside the tracked prefix: a
                            // scan would stop exactly there.
                            matched
                        } else {
                            len + summary.matching_prefix(&chain.suffix(len))
                        })
                    });
                    let a = tracked.unwrap_or_else(|| {
                        let hashes = full.get_or_insert_with(|| chain.hashes());
                        summary.matching_prefix(hashes)
                    });
                    best = best.max((a + adapter_blocks) as f64 - penalty * load as f64);
                    a
                }
            };
            views.push(ReplicaView { load, affinity_blocks, adapter_blocks, healthy });
        }
        views
    }

    /// Ship a leased chain's blocks to `dest` instead of letting the next
    /// turn recompute them (DESIGN.md §18). The decision is a cost-model
    /// call on the destination's config: when the modeled transfer time
    /// beats prefilling the same blocks from token zero, the chain is
    /// installed into `dest`'s pool under the lease and the transfer time
    /// is charged on `dest`'s clock — the blocks are unusable before they
    /// arrive, so the cost lands in the next turn's TTFT exactly like the
    /// (more expensive) prefill it replaces would have. When the model
    /// says recompute wins — or the destination cannot take the blocks —
    /// NOTHING is mutated beyond the fallback counter, so the path is
    /// bit-identical to a fleet without migration.
    ///
    /// Returns the number of blocks installed (0 = recompute fallback).
    fn migrate_lease_to(&mut self, lease: u64, chain: &ChainRef, dest: usize) -> usize {
        if chain.is_empty() || self.health[dest] != ReplicaHealth::Up {
            return 0;
        }
        let cm = CostModel::new(&self.replicas[dest].cfg);
        if !cm.migration_wins(chain.len()) {
            self.router.stats.migration_recompute_fallbacks += 1;
            return 0;
        }
        // Exactly one replica ever pins a session's chain: drop any stale
        // copy elsewhere before installing (the draining source keeps its
        // unpinned committed blocks — same as a lease break — while a
        // down source already lost everything at `fail_storage`).
        for (i, r) in self.replicas.iter_mut().enumerate() {
            if i != dest {
                r.release_prefix_lease(lease);
            }
        }
        let now = self.clock();
        let r = &mut self.replicas[dest];
        if !r.has_work() && r.clock() < now {
            r.advance_clock_to(now);
        }
        let installed = r.install_migrated_lease(lease, chain);
        if installed == 0 {
            // No room at the destination: the prefix recomputes on demand.
            self.router.stats.migration_recompute_fallbacks += 1;
            return 0;
        }
        let arrival = r.clock() + cm.migration_time(installed);
        r.advance_clock_to(arrival);
        self.router.stats.migrations += 1;
        self.router.stats.migrated_blocks += installed as u64;
        installed
    }
}

/// The shared per-replica config summary (replicas are identical by
/// construction; a single engine is a fleet of one).
fn config_summary(cfg: &EngineConfig) -> ReplicaConfigSummary {
    ReplicaConfigSummary {
        model: cfg.model.name.clone(),
        block_size: cfg.cache.block_size,
        total_blocks: cfg.cache.num_blocks(),
        max_batch_tokens: cfg.scheduler.max_batch_tokens,
        max_num_seqs: cfg.scheduler.max_num_seqs,
        admission_watermark: cfg.scheduler.admission_watermark,
        base_aligned_hashing: cfg.cache.base_aligned_hashing,
        adapter_paging: cfg.cache.adapter_paging,
    }
}

/// One engine's stats row, shared by the fleet snapshot and the
/// single-engine `GET /cluster` document.
fn replica_stats<E: Executor>(
    i: usize,
    r: &Engine<E>,
    routed: u64,
    health: &'static str,
) -> ReplicaStats {
    ReplicaStats {
        replica: i,
        health,
        clock: r.clock(),
        running: r.num_running(),
        waiting: r.num_waiting(),
        finished: r.metrics.requests_finished,
        free_blocks: r.num_free_blocks(),
        total_blocks: r.num_total_blocks(),
        committed_blocks: r.routing_summary().committed_blocks(),
        hit_rate: r.kv_stats().hit_rate(),
        routed,
        resident_adapters: r.residency().resident_ids(),
        adapter_resident_blocks: r.residency().resident_blocks(),
        adapter_loads: r.residency().stats().loads,
        adapter_evictions: r.residency().stats().evictions,
    }
}

/// A one-replica `ClusterStats` for a single engine: `GET /cluster` on a
/// single-engine server returns this instead of 404 (API consistency —
/// dashboards built against the fleet shape work unchanged). Every
/// submission trivially "routed" to replica 0; policy reports "single".
pub fn single_engine_stats<E: Executor>(e: &Engine<E>) -> ClusterStats {
    let mut routing = RoutingMetrics::new(1);
    routing.routed[0] = e.metrics.requests_received;
    ClusterStats {
        policy: "single",
        config: config_summary(&e.cfg),
        replicas: vec![replica_stats(0, e, e.metrics.requests_received, "up")],
        routing,
        aggregate_hit_rate: e.kv_stats().hit_rate(),
        aggregate_adapter_hit_rate: e.residency().stats().hit_rate(),
    }
}

impl<E: Executor> EngineDriver for Cluster<E> {
    fn submit_salted(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
    ) -> anyhow::Result<RequestId> {
        anyhow::ensure!(
            self.num_healthy() > 0,
            "no healthy replicas: the whole fleet is down or draining"
        );
        let (views, chain) = self.views_for(target, &prompt, cache_salt);
        let placement = self.router.choose(&views);
        let now = self.clock();
        let r = &mut self.replicas[placement.replica];
        // An idle replica's clock lags only because nothing advanced it;
        // the request really arrives at fleet time, so sync forward. Busy
        // replicas keep their own timeline (jumping it would stretch
        // in-flight work). Under the event drive this approximation is
        // tight — arrivals are gated on the fleet clock every step, so the
        // sync target is at most one scheduling quantum past the nominal
        // arrival. (Advancing before a rejected submission is harmless:
        // the clock only moves forward and no request is created.)
        if !r.has_work() && r.clock() < now {
            r.advance_clock_to(now);
        }
        let id = r.submit_prehashed(target, prompt, params, priority, cache_salt, chain)?;
        // Count the placement only now: rejected submissions must not
        // skew the routing stats.
        self.router.record(placement);
        Ok(id)
    }

    /// Session stickiness: a conversation turn lands on the replica that
    /// ran its previous turn — `peer`'s replica is a construction-time
    /// fact (ids are partitioned `replica = id % n`, overridden by the
    /// failover re-home map), so no summary scoring is needed and the
    /// warm prefix is guaranteed co-located. First turns (no peer) fall
    /// through to the routing policy; so does a turn whose replica is
    /// down or draining — the conversation re-sticks wherever its chain
    /// scores best (PrefixAffinity finds any surviving copy; cold via the
    /// least-loaded fallback if nothing survives), counted as a re-stick.
    fn submit_sticky(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
        peer: Option<RequestId>,
    ) -> anyhow::Result<RequestId> {
        let Some(peer) = peer else {
            return self.submit_salted(target, prompt, params, priority, cache_salt);
        };
        let ri = self.replica_of(peer);
        if self.health[ri] != ReplicaHealth::Up {
            self.router.stats.resticks += 1;
            return self.submit_salted(target, prompt, params, priority, cache_salt);
        }
        let now = self.clock();
        let r = &mut self.replicas[ri];
        // Same idle-clock sync as routed submission: the turn arrives at
        // fleet time even if its replica sat idle between turns.
        if !r.has_work() && r.clock() < now {
            r.advance_clock_to(now);
        }
        let id = r.submit_salted(target, prompt, params, priority, cache_salt)?;
        self.router.record_sticky(ri);
        Ok(id)
    }

    /// The hot path for conversation turns at scale: the session layer
    /// already extended its cached chain by the delta turn, so neither
    /// the sticky fast path (no routing scan at all) nor the re-stick
    /// fallback (scored via [`Cluster::views_for_chain`] with the lease
    /// hint) rehashes the conversation history — per-turn placement work
    /// is O(delta + replicas), independent of how long the session is.
    fn submit_sticky_prehashed(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
        peer: Option<RequestId>,
        lease: Option<u64>,
        chain: ChainRef,
    ) -> anyhow::Result<RequestId> {
        let sticky = peer.map(|p| self.replica_of(p));
        match sticky {
            Some(ri) if self.health[ri] == ReplicaHealth::Up => {
                let now = self.clock();
                let r = &mut self.replicas[ri];
                if !r.has_work() && r.clock() < now {
                    r.advance_clock_to(now);
                }
                let id =
                    r.submit_prehashed(target, prompt, params, priority, cache_salt, chain)?;
                self.router.record_sticky(ri);
                Ok(id)
            }
            unstuck => {
                anyhow::ensure!(
                    self.num_healthy() > 0,
                    "no healthy replicas: the whole fleet is down or draining"
                );
                if unstuck.is_some() {
                    // The conversation's replica is down or draining:
                    // re-stick through the routing policy.
                    self.router.stats.resticks += 1;
                }
                // Chain-blind policies never look at affinity; don't pay
                // for probes they'd ignore (mirrors `views_for`).
                let empty = ChainRef::empty();
                let score_chain =
                    if self.router.needs_chain() { &chain } else { &empty };
                let views = self.views_for_chain(target, score_chain, lease);
                let placement = self.router.choose(&views);
                // Drain migration (DESIGN.md §18): if the conversation's
                // old replica still pins its chain — only a DRAINING
                // source can; a down one released everything at
                // `fail_storage` — and this turn extends that chain but
                // lands elsewhere, ship the pinned blocks to the new home
                // instead of recomputing them (cost model permitting).
                if self.replicas[0].cfg.cache.prefix_migration {
                    if let Some(key) = lease {
                        let src = (0..self.replicas.len()).find_map(|i| {
                            self.replicas[i].lease_chain(key).map(|c| (i, c))
                        });
                        if let Some((src, leased)) = src {
                            if src != placement.replica
                                && !leased.is_empty()
                                && chain.is_extension_of(&leased)
                            {
                                self.migrate_lease_to(key, &leased, placement.replica);
                            }
                        }
                    }
                }
                let now = self.clock();
                let r = &mut self.replicas[placement.replica];
                if !r.has_work() && r.clock() < now {
                    r.advance_clock_to(now);
                }
                let id =
                    r.submit_prehashed(target, prompt, params, priority, cache_salt, chain)?;
                self.router.record(placement);
                Ok(id)
            }
        }
    }

    fn watch(&mut self, id: RequestId) {
        let ri = self.replica_of(id);
        self.replicas[ri].watch(id);
    }

    fn unwatch(&mut self, id: RequestId) {
        let ri = self.replica_of(id);
        self.replicas[ri].unwatch(id);
    }

    fn take_events(&mut self) -> Vec<TurnEvent> {
        let mut out = Vec::new();
        for r in &mut self.replicas {
            out.append(&mut r.take_events());
        }
        out
    }

    /// The lease lives where the blocks live: on `peer`'s replica (the
    /// turn that just committed the chain there, located through the
    /// failover re-home map). Any stale copy of the lease on other
    /// replicas — a conversation migrates when its replica fails or
    /// drains — is released first, so exactly one replica ever pins a
    /// session's chain. No peer = no turn has run = nothing to pin; a
    /// down peer replica = the blocks are gone = nothing to pin either.
    fn acquire_lease(
        &mut self,
        lease: u64,
        tokens: &[u32],
        cache_salt: u64,
        peer: Option<RequestId>,
    ) -> usize {
        let Some(peer) = peer else { return 0 };
        let ri = self.replica_of(peer);
        for (i, r) in self.replicas.iter_mut().enumerate() {
            if i != ri {
                r.release_prefix_lease(lease);
            }
        }
        if self.health[ri] == ReplicaHealth::Down {
            return 0;
        }
        self.replicas[ri].lease_prefix(lease, tokens, cache_salt)
    }

    /// Prehashed form of [`EngineDriver::acquire_lease`]: the session
    /// layer's cached chain goes straight to the replica's lease table,
    /// which extends an existing lease in O(delta) — no per-turn rehash
    /// of the conversation history, no full re-pin.
    fn acquire_lease_prehashed(
        &mut self,
        lease: u64,
        chain: &ChainRef,
        peer: Option<RequestId>,
    ) -> usize {
        let Some(peer) = peer else { return 0 };
        let ri = self.replica_of(peer);
        for (i, r) in self.replicas.iter_mut().enumerate() {
            if i != ri {
                r.release_prefix_lease(lease);
            }
        }
        if self.health[ri] == ReplicaHealth::Down {
            return 0;
        }
        self.replicas[ri].lease_prefix_prehashed(lease, chain)
    }

    fn release_lease(&mut self, lease: u64) {
        for r in &mut self.replicas {
            r.release_prefix_lease(lease);
        }
    }

    /// One fleet step: every live replica with work advances by one batch
    /// (they are parallel machines). Down replicas never step — their
    /// work was evacuated at failure, and a dead machine computes
    /// nothing. False only when no replica progressed.
    fn step(&mut self) -> bool {
        let mut progressed = false;
        for (i, r) in self.replicas.iter_mut().enumerate() {
            if self.health[i] == ReplicaHealth::Down {
                continue;
            }
            if r.has_work() {
                progressed |= r.step();
            }
        }
        progressed
    }

    fn clock(&self) -> f64 {
        self.replicas.iter().map(|r| r.clock()).fold(0.0, f64::max)
    }

    fn advance_clock_to(&mut self, t: f64) {
        for r in &mut self.replicas {
            if r.clock() < t {
                r.advance_clock_to(t);
            }
        }
    }

    fn has_work(&self) -> bool {
        self.replicas.iter().any(|r| r.has_work())
    }

    fn num_waiting(&self) -> usize {
        self.replicas.iter().map(|r| r.num_waiting()).sum()
    }

    fn num_running(&self) -> usize {
        self.replicas.iter().map(|r| r.num_running()).sum()
    }

    fn take_finished(&mut self) -> Vec<RequestOutput> {
        let mut out = Vec::new();
        for r in &mut self.replicas {
            out.append(&mut r.take_finished());
        }
        out
    }

    fn finished_pending(&self) -> usize {
        self.replicas.iter().map(|r| r.finished_pending()).sum()
    }

    fn take_finished_where<F: FnMut(&RequestOutput) -> bool>(
        &mut self,
        mut pred: F,
    ) -> Vec<RequestOutput> {
        let mut out = Vec::new();
        for r in &mut self.replicas {
            out.extend(r.take_finished_where(&mut pred));
        }
        out
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    fn config(&self) -> &EngineConfig {
        &self.replicas[0].cfg
    }

    fn registry(&self) -> &AdapterRegistry {
        &self.replicas[0].registry
    }

    /// Fleet exposition: aggregated single-engine families (counters and
    /// histograms summed, clock = makespan) + the fleet-level per-stage
    /// series + routing counters + per-replica labeled families. Every
    /// family appears exactly once, and — scrape path — nothing O(total
    /// requests served) is copied: only scalars and fixed-bucket
    /// histograms aggregate, and the stage series render by reference.
    fn render_prometheus(&self) -> String {
        let mut agg = Metrics::new();
        agg.absorb_scalars(&self.metrics);
        for r in &self.replicas {
            agg.absorb_scalars(&r.metrics);
        }
        let mut s = agg.render_prometheus();
        // The coordinator's stage series and the session layer's per-turn
        // series are recorded through metrics_mut(), i.e. on the fleet
        // registry — replicas never carry any (and the aggregated scalars
        // above rendered an empty turn series, so each family appears
        // exactly once).
        s.push_str(&Metrics::render_turn_series(&self.metrics.turn));
        s.push_str(&Metrics::render_stage_series(&self.metrics.stage));
        s.push_str(&self.router.stats.render_prometheus());
        let per: Vec<&Metrics> = self.replicas.iter().map(|r| &r.metrics).collect();
        s.push_str(&Metrics::render_replica_families(&per));
        s
    }

    fn cluster_stats(&self) -> Option<ClusterStats> {
        Some(self.stats())
    }

    fn fail_replica(&mut self, i: usize) -> anyhow::Result<FailoverReport> {
        Cluster::fail_replica(self, i)
    }

    fn drain_replica(&mut self, i: usize) -> anyhow::Result<()> {
        Cluster::drain_replica(self, i)
    }

    fn restore_replica(&mut self, i: usize) -> anyhow::Result<()> {
        Cluster::restore_replica(self, i)
    }

    fn note_resticks(&mut self, n: u64) {
        self.router.stats.resticks += n;
    }

    /// Re-home a session's pinned chain after failover (DESIGN.md §18):
    /// the destination is the peer's replica when that replica is up (the
    /// session's requeued turn already landed there, so the blocks must
    /// follow it), else the routing policy's pick for the chain — chosen
    /// but NOT recorded, because a migration is not a request placement.
    /// Gated on `cache.prefix_migration`; off (the default), every call
    /// returns 0 and the fleet recomputes exactly as before the flag
    /// existed.
    fn migrate_lease(&mut self, lease: u64, chain: &ChainRef, peer: Option<RequestId>) -> usize {
        if !self.replicas[0].cfg.cache.prefix_migration || chain.is_empty() {
            return 0;
        }
        // Decide BEFORE picking a destination: `Router::choose` may
        // advance policy state (the round-robin cursor), and a declined
        // migration must leave the fleet bit-identical to one that never
        // considered migrating. Replicas are identical by construction,
        // so replica 0's cost model speaks for any destination.
        if !CostModel::new(&self.replicas[0].cfg).migration_wins(chain.len()) {
            self.router.stats.migration_recompute_fallbacks += 1;
            return 0;
        }
        let dest = match peer.map(|p| self.replica_of(p)) {
            Some(ri) if self.health[ri] == ReplicaHealth::Up => ri,
            _ => {
                if self.num_healthy() == 0 {
                    return 0;
                }
                let views = self.views_for_chain(ModelTarget::Base, chain, Some(lease));
                self.router.choose(&views).replica
            }
        };
        self.migrate_lease_to(lease, chain, dest)
    }

    fn note_session_forks(&mut self, n: u64) {
        self.router.stats.session_forks += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::AdapterId;
    use crate::config::presets;
    use crate::pipeline::workload;
    use crate::simulator::SimExecutor;

    fn cluster(n: usize, policy: RoutePolicy) -> Cluster<SimExecutor> {
        Cluster::from_factory(n, policy, |_| {
            let cfg = presets::granite_8b();
            let reg = workload::build_registry(2, cfg.model.vocab_size, true);
            let exec = SimExecutor::new(&cfg);
            Engine::with_registry(cfg, reg, exec)
        })
        .unwrap()
    }

    /// Two-replica affinity fleet with prefix migration switchable — the
    /// migration tests run both arms of the flag on otherwise identical
    /// fleets and compare.
    fn session_cluster(migrate: bool) -> Cluster<SimExecutor> {
        Cluster::from_factory(2, RoutePolicy::PrefixAffinity, |_| {
            let mut cfg = presets::granite_8b();
            cfg.cache.prefix_migration = migrate;
            let reg = workload::build_registry(2, cfg.model.vocab_size, true);
            let exec = SimExecutor::new(&cfg);
            Engine::with_registry(cfg, reg, exec)
        })
        .unwrap()
    }

    #[test]
    fn ids_are_fleet_unique_and_interleaved() {
        let mut c = cluster(3, RoutePolicy::RoundRobin);
        let mut ids = Vec::new();
        for i in 0..6 {
            ids.push(
                c.submit(
                    ModelTarget::Base,
                    vec![1 + i; 32],
                    SamplingParams { max_new_tokens: 2, ..Default::default() },
                )
                .unwrap(),
            );
        }
        let mut sorted = ids.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 6, "duplicate ids across replicas: {ids:?}");
        // RR: request k lands on replica k%3, which issues k%3 + 3*floor(k/3).
        assert_eq!(ids, (0..6).map(RequestId).collect::<Vec<_>>());
        c.run_until_idle();
        assert_eq!(c.take_finished().len(), 6);
        assert!(!c.has_work());
    }

    #[test]
    fn single_replica_cluster_matches_plain_engine() {
        let run = |clustered: bool| {
            let prompt: Vec<u32> = (0..256).collect();
            let p = SamplingParams { max_new_tokens: 16, ..Default::default() };
            if clustered {
                let mut c = cluster(1, RoutePolicy::RoundRobin);
                c.submit(ModelTarget::Base, prompt.clone(), p).unwrap();
                c.run_until_idle();
                (c.clock(), c.take_finished().len())
            } else {
                let cfg = presets::granite_8b();
                let reg = workload::build_registry(2, cfg.model.vocab_size, true);
                let mut e = Engine::with_registry(cfg.clone(), reg, SimExecutor::new(&cfg));
                e.submit(ModelTarget::Base, prompt.clone(), p).unwrap();
                e.run_until_idle();
                (e.clock(), e.take_finished().len())
            }
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn affinity_routes_follow_up_to_warm_replica() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let prompt: Vec<u32> = (0..256).collect();
        let p = SamplingParams { max_new_tokens: 16, ..Default::default() };
        // Cold conversation: least-loaded fallback → replica 0.
        c.submit(ModelTarget::Base, prompt.clone(), p).unwrap();
        c.run_until_idle();
        let first = c.take_finished().pop().unwrap();
        assert_eq!(c.router().stats.affinity_fallbacks, 1);
        // Follow-up extends the conversation: must land on replica 0 and
        // hit its cached prefix, not re-prefill on replica 1.
        let mut follow = prompt.clone();
        follow.extend(&first.output_tokens);
        follow.push(7);
        c.submit(ModelTarget::Base, follow, p).unwrap();
        c.run_until_idle();
        let second = c.take_finished().pop().unwrap();
        assert_eq!(c.router().stats.affinity_hits, 1);
        assert_eq!(c.router().stats.routed, vec![2, 0]);
        assert_eq!(second.num_cached_tokens, 256, "warm-replica prefix hit");
        // And the adapter direction: an aLoRA eval over the conversation
        // shares the base prefix, so it must land warm too.
        let mut ev = prompt.clone();
        ev.extend(&first.output_tokens);
        ev.extend(workload::invocation_for(c.config().model.vocab_size, 0));
        c.submit(ModelTarget::Adapter(AdapterId(0)), ev, p).unwrap();
        c.run_until_idle();
        let eval = c.take_finished().pop().unwrap();
        assert!(eval.num_cached_tokens >= 256, "cross-model affinity hit");
        assert_eq!(c.router().stats.routed, vec![3, 0]);
    }

    #[test]
    fn cluster_stats_and_prometheus_render() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        c.submit(
            ModelTarget::Base,
            (0..64).collect(),
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        )
        .unwrap();
        c.run_until_idle();
        let st = c.stats();
        assert_eq!(st.policy, "prefix-affinity");
        assert_eq!(st.replicas.len(), 2);
        assert_eq!(st.routing.total_routed(), 1);
        assert!(st.replicas.iter().any(|r| r.committed_blocks > 0));
        // Config summary rides along so dashboards don't need out-of-band
        // config (satellite: per-replica block budget + paging flag).
        assert_eq!(st.config.model, "granite-8b");
        assert_eq!(st.config.total_blocks, 21_944);
        assert!(!st.config.adapter_paging);
        assert!(st.replicas.iter().all(|r| r.resident_adapters.is_empty()));
        let j = st.to_json().to_string();
        assert!(j.contains("\"policy\":\"prefix-affinity\""), "{j}");
        assert!(j.contains("\"config\":{"), "{j}");
        assert!(j.contains("\"total_blocks\":21944"), "{j}");
        assert!(j.contains("\"adapter_paging\":false"), "{j}");
        assert!(j.contains("\"resident_adapters\":[]"), "{j}");
        let prom = c.render_prometheus();
        assert!(prom.contains("alora_serve_requests_finished_total 1"), "{prom}");
        assert!(prom.contains("alora_serve_router_requests_routed_total{replica=\"0\"}"));
        assert!(prom.contains("alora_serve_replica_clock_seconds{replica=\"1\"}"));
    }

    #[test]
    fn rejected_submission_leaves_routing_stats_untouched() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let max = c.config().scheduler.max_seq_len as usize;
        let err = c.submit(
            ModelTarget::Base,
            vec![1; max + 1],
            SamplingParams { max_new_tokens: 1, ..Default::default() },
        );
        assert!(err.is_err());
        assert_eq!(c.router().stats.total_routed(), 0);
        assert_eq!(c.router().stats.affinity_fallbacks, 0);
    }

    #[test]
    fn adapter_affinity_converges_replicas_on_hot_subsets() {
        // Paged fleet: 128-block budget per replica, 3 aLoRAs × 32 weight
        // blocks. Round 1 spreads cold adapters by load; from round 2 on,
        // each adapter's requests go home to the replica holding its
        // weights — replicas converge on disjoint hot subsets instead of
        // all replicas paging all adapters (S-LoRA-style placement).
        let mut c = Cluster::from_factory(2, RoutePolicy::AdapterAffinity, |_| {
            let mut cfg = presets::granite_8b();
            cfg.scheduler.max_seq_len = 2048;
            cfg.cache.max_kv_tokens = 2048; // 128 blocks
            cfg.cache.adapter_paging = true;
            let reg = workload::build_registry(3, cfg.model.vocab_size, true);
            let exec = SimExecutor::new(&cfg);
            Engine::with_registry(cfg, reg, exec)
        })
        .unwrap();
        let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
        let mut rng = crate::util::rng::Rng::new(3);
        let vocab = c.config().model.vocab_size;
        for _round in 0..3 {
            for a in 0..3u32 {
                let prompt = workload::prompt(&mut rng, 256, vocab);
                c.submit(ModelTarget::Adapter(AdapterId(a)), prompt, p).unwrap();
            }
            c.run_until_idle();
        }
        let st = c.stats();
        assert_eq!(st.config.total_blocks, 128);
        assert!(st.config.adapter_paging);
        // Every adapter found a home; the fleet holds each exactly once.
        let mut all: Vec<u32> = st
            .replicas
            .iter()
            .flat_map(|r| r.resident_adapters.clone())
            .collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2], "disjoint hot subsets: {st:?}");
        // Rounds 2 and 3 were all residency hits: 6 of 9 admissions warm,
        // and no adapter was ever evicted (stable placement, no thrash).
        assert!((c.aggregate_adapter_hit_rate() - 6.0 / 9.0).abs() < 1e-12);
        let loads: u64 = st.replicas.iter().map(|r| r.adapter_loads).sum();
        let evictions: u64 = st.replicas.iter().map(|r| r.adapter_evictions).sum();
        assert_eq!(loads, 3, "one load per adapter, ever");
        assert_eq!(evictions, 0);
        assert_eq!(c.router().stats.affinity_hits, 6);
        let j = st.to_json().to_string();
        assert!(j.contains("\"aggregate_adapter_hit_rate\""), "{j}");
    }

    #[test]
    fn session_turns_stick_to_their_replica_and_stream_events() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let mut mgr = crate::session::SessionManager::new();
        let sid = mgr.create(0);
        let t1 = mgr
            .run_turn(&mut c, sid, ModelTarget::Base, (0..256).collect(), 16, true)
            .unwrap();
        assert_eq!(t1.cached_tokens, 0, "cold first turn");
        assert_eq!(c.router().stats.affinity_fallbacks, 1);
        // Follow-up turn: pinned to the conversation's replica without
        // scoring, and warm by construction. Watched: events flow back
        // through the fleet-uniform surface.
        let (_tid, rid) = mgr
            .begin_turn(&mut c, sid, ModelTarget::Base, (900..964).collect(), 16, true)
            .unwrap();
        c.watch(rid);
        let out = loop {
            if let Some(o) = c.take_finished_where(|o| o.id == rid).pop() {
                break o;
            }
            assert!(c.step(), "cluster stalled");
        };
        let evs = c.take_events();
        assert!(evs.iter().all(|e| e.id() == rid));
        assert!(matches!(
            evs.last(),
            Some(crate::request::TurnEvent::Finished { .. })
        ));
        let t2 = mgr.complete_turn(&mut c, sid, &out).unwrap();
        assert_eq!(c.router().stats.sticky_routed, 1);
        assert_eq!(c.router().stats.routed, vec![2, 0]);
        assert!(t2.cached_tokens >= 256, "sticky turn warm: {}", t2.cached_tokens);
        // The lease pins the chain on the conversation's replica only.
        assert!(c.replica(0).leased_blocks() > 0);
        assert_eq!(c.replica(1).leased_blocks(), 0);
        let j = c.stats().to_json().to_string();
        assert!(j.contains("\"sticky_routed\":1"), "{j}");
        // Deleting the session releases the lease fleet-wide.
        mgr.delete(&mut c, sid).unwrap();
        assert_eq!(c.replica(0).leased_blocks(), 0);
        c.replica(0).check_invariants().unwrap();
    }

    #[test]
    fn fail_replica_requeues_in_flight_and_waiting_with_ids_preserved() {
        let mut c = cluster(2, RoutePolicy::RoundRobin);
        let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
        let mut ids = Vec::new();
        for i in 0..6u32 {
            ids.push(
                c.submit(ModelTarget::Base, vec![10 + i; 64], p).unwrap(),
            );
        }
        // Get replica 1's share in flight (prefilling/decoding), then
        // kill it: ids 1, 3, 5 live there (RR interleave).
        for _ in 0..2 {
            c.step();
        }
        let report = c.fail_replica(1).unwrap();
        assert_eq!(c.health(1), ReplicaHealth::Down);
        assert_eq!(report.requeued, 3);
        assert!(report.rejected.is_empty());
        assert_eq!(c.router().stats.requeued_requests, 3);
        assert_eq!(c.router().stats.replica_failures, 1);
        assert_eq!(c.replica(1).num_running() + c.replica(1).num_waiting(), 0);
        // Every caller still gets its output, under its original id.
        c.run_until_idle();
        let outs = c.take_finished();
        let mut got: Vec<RequestId> = outs.iter().map(|o| o.id).collect();
        got.sort();
        assert_eq!(got, ids, "zero lost requests, fleet-unique ids preserved");
        // The victim is cold and empty; the survivor holds all the state.
        assert_eq!(c.replica(1).routing_summary().committed_blocks(), 0);
        assert_eq!(c.replica(1).num_free_blocks(), c.replica(1).num_total_blocks());
        c.replica(0).check_invariants().unwrap();
        c.replica(1).check_invariants().unwrap();
        // Fleet-wide received counter is not double-counted by the requeue.
        assert_eq!(c.aggregate_metrics().requests_received, 6);
        assert_eq!(c.aggregate_metrics().requests_finished, 6);
    }

    #[test]
    fn health_transition_guards() {
        let mut c = cluster(2, RoutePolicy::RoundRobin);
        // Restore an up replica: refused.
        assert!(c.restore_replica(0).unwrap_err().to_string().contains("already up"));
        // Unknown replica index.
        assert!(c.fail_replica(9).unwrap_err().to_string().contains("no replica 9"));
        c.fail_replica(1).unwrap();
        // Double fail refused; failing the last healthy refused.
        assert!(c.fail_replica(1).unwrap_err().to_string().contains("already down"));
        assert!(c
            .fail_replica(0)
            .unwrap_err()
            .to_string()
            .contains("no healthy survivor"));
        assert!(c.drain_replica(0).unwrap_err().to_string().contains("last healthy"));
        // Draining a down replica refused; restore brings it back up.
        assert!(c.drain_replica(1).is_err());
        c.restore_replica(1).unwrap();
        assert_eq!(c.health(1), ReplicaHealth::Up);
        // Now draining 0 works (1 is healthy again), and submissions
        // avoid it.
        c.drain_replica(0).unwrap();
        let p = SamplingParams { max_new_tokens: 2, ..Default::default() };
        for i in 0..3 {
            c.submit(ModelTarget::Base, vec![i + 1; 32], p).unwrap();
        }
        assert_eq!(c.router().stats.routed, vec![0, 3], "drained replica excluded");
        c.run_until_idle();
    }

    #[test]
    fn drain_finishes_in_flight_work_before_exclusion() {
        let mut c = cluster(2, RoutePolicy::RoundRobin);
        let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
        let a = c.submit(ModelTarget::Base, vec![1; 64], p).unwrap(); // replica 0
        let b = c.submit(ModelTarget::Base, vec![2; 64], p).unwrap(); // replica 1
        c.step();
        c.drain_replica(1).unwrap();
        assert_eq!(c.health(1), ReplicaHealth::Draining);
        // New traffic all lands on replica 0...
        for i in 0..4 {
            c.submit(ModelTarget::Base, vec![10 + i; 32], p).unwrap();
        }
        assert_eq!(c.router().stats.routed[1], 1, "no new placements while draining");
        // ...while the draining replica still finishes its own request.
        c.run_until_idle();
        let outs = c.take_finished();
        assert!(outs.iter().any(|o| o.id == a));
        assert!(outs.iter().any(|o| o.id == b), "draining replica finished its work");
        assert_eq!(c.replica(1).metrics.requests_finished, 1);
        c.replica(1).check_invariants().unwrap();
    }

    #[test]
    fn failed_replica_session_resticks_and_rebuilds_lease() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let mut mgr = crate::session::SessionManager::new();
        let sid = mgr.create(0);
        let t1 = mgr
            .run_turn(&mut c, sid, ModelTarget::Base, (0..256).collect(), 16, true)
            .unwrap();
        assert_eq!(t1.cached_tokens, 0);
        let home = (mgr.get(sid).unwrap().last_request.unwrap().0 % 2) as usize;
        assert!(c.replica(home).leased_blocks() > 0);
        // Kill the conversation's replica between turns: the lease
        // orphans, the repair clears stickiness, and the next turn
        // re-sticks cold on the survivor — recomputed tokens, no error.
        let report = c.fail_replica(home).unwrap();
        assert_eq!(report.requeued, 0, "nothing was in flight");
        assert_eq!(report.orphaned_leases, vec![sid.0]);
        let (leases, unstuck, aborted) = mgr.repair_after_failover(&mut c, &report);
        assert_eq!((leases, unstuck, aborted), (1, 1, 0));
        assert_eq!(mgr.get(sid).unwrap().leased_blocks, 0);
        assert!(mgr.get(sid).unwrap().last_request.is_none());
        assert_eq!(c.router().stats.resticks, 1);
        let t2 = mgr
            .run_turn(&mut c, sid, ModelTarget::Base, (900..932).collect(), 16, true)
            .unwrap();
        assert_eq!(t2.cached_tokens, 0, "chain transparently recomputed");
        let survivor = 1 - home;
        assert!(c.replica(survivor).leased_blocks() > 0, "lease rebuilt");
        assert_eq!(c.router().stats.orphaned_leases, 1);
        // Turn 3 is warm again on the survivor, sticky this time.
        let t3 = mgr
            .run_turn(&mut c, sid, ModelTarget::Base, (950..966).collect(), 16, true)
            .unwrap();
        assert!(t3.cached_tokens > 256, "re-warmed: {}", t3.cached_tokens);
        assert_eq!(c.router().stats.sticky_routed, 1, "only the re-warmed turn stuck");
        // The fleet document reports the failover activity alongside the
        // per-replica health — not just Prometheus.
        let j = c.stats().to_json().to_string();
        assert!(j.contains("\"replica_failures\":1"), "{j}");
        assert!(j.contains("\"orphaned_leases\":1"), "{j}");
        assert!(j.contains("\"resticks\":1"), "{j}");
        assert!(j.contains("\"health\":\"down\""), "{j}");
        assert!(j.contains("\"health\":\"up\""), "{j}");
        mgr.delete(&mut c, sid).unwrap();
        c.replica(survivor).check_invariants().unwrap();
    }

    #[test]
    fn sticky_turn_to_draining_replica_resticks_via_policy() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let mut mgr = crate::session::SessionManager::new();
        let sid = mgr.create(0);
        mgr.run_turn(&mut c, sid, ModelTarget::Base, (0..256).collect(), 16, true)
            .unwrap();
        let home = (mgr.get(sid).unwrap().last_request.unwrap().0 % 2) as usize;
        c.drain_replica(home).unwrap();
        // The sticky peer is draining: the turn re-sticks via the policy.
        // PrefixAffinity scores only healthy replicas, and the chain lives
        // on the draining one — so the turn lands cold on the other.
        let t2 = mgr
            .run_turn(&mut c, sid, ModelTarget::Base, (900..932).collect(), 16, true)
            .unwrap();
        assert_eq!(c.router().stats.resticks, 1);
        assert_eq!(c.router().stats.sticky_routed, 0);
        assert_eq!(t2.cached_tokens, 0, "drained replica's cache unreachable");
        // The lease moved: exactly one replica pins the chain, and it is
        // the healthy one.
        let healthy = 1 - home;
        assert!(c.replica(healthy).leased_blocks() > 0);
        assert_eq!(c.replica(home).leased_blocks(), 0, "stale lease released");
        mgr.delete(&mut c, sid).unwrap();
    }

    #[test]
    fn turn_metrics_counted_exactly_once_in_aggregate_and_scrape() {
        // ISSUE-5 satellite: in cluster mode complete_turn records the
        // turn series on the fleet registry while aggregate_metrics()
        // absorbs fleet + every replica — samples must appear exactly
        // once, and repeated aggregation must be idempotent.
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let mut mgr = crate::session::SessionManager::new();
        let sid = mgr.create(0);
        for t in 0..3u32 {
            mgr.run_turn(
                &mut c,
                sid,
                ModelTarget::Base,
                (t * 100..t * 100 + 64).collect(),
                8,
                true,
            )
            .unwrap();
        }
        // The series lives on the fleet registry only — replicas carry none.
        assert_eq!(c.metrics.turn.count(), 3);
        assert!(c.replicas.iter().all(|r| r.metrics.turn.count() == 0));
        let agg = c.aggregate_metrics();
        assert_eq!(agg.turn.count(), 3, "each turn sampled exactly once");
        assert_eq!(agg.requests_finished, 3);
        // Idempotence: aggregating again yields the same counts (absorb
        // never mutates the sources).
        let agg2 = c.aggregate_metrics();
        assert_eq!(agg2.turn.count(), 3);
        assert_eq!(agg2.requests_finished, agg.requests_finished);
        assert_eq!(agg2.all.count(), agg.all.count());
        // The scrape renders the turn family exactly once, with the fleet
        // count — not doubled by the aggregated (empty) registry's.
        let prom = c.render_prometheus();
        assert_eq!(prom.matches("# HELP alora_serve_turns_total").count(), 1);
        assert!(prom.contains("alora_serve_turns_total 3"), "{prom}");
        let prom2 = c.render_prometheus();
        assert_eq!(prom, prom2, "scrape is idempotent");
        mgr.delete(&mut c, sid).unwrap();
    }

    #[test]
    fn least_loaded_balances_cold_traffic() {
        let mut c = cluster(2, RoutePolicy::LeastLoaded);
        for i in 0..8 {
            c.submit(
                ModelTarget::Base,
                vec![100 + i; 64],
                SamplingParams { max_new_tokens: 4, ..Default::default() },
            )
            .unwrap();
        }
        let routed = c.router().stats.routed.clone();
        assert_eq!(routed, vec![4, 4], "cold uniform load must split evenly");
        c.run_until_idle();
    }

    #[test]
    fn relocation_refresh_is_constant_time_and_evicts_in_order() {
        // ISSUE-8 satellite: re-relocating an id must not scan the order
        // queue. The refreshed entry re-enters at the back under a fresh
        // epoch; the stale front entry drains as a tombstone without
        // forgetting the live re-home.
        let mut c = cluster(2, RoutePolicy::RoundRobin);
        let x = RequestId(9); // id % 2 == 1 once forgotten
        c.note_relocation(x, 0);
        c.note_relocation(x, 0); // refresh: front entry is now a tombstone
        assert_eq!(c.replica_of(x), 0);
        // Fill the window. The tombstone is evicted first (it dilutes
        // capacity by one slot) but x's live entry — re-stamped at the
        // back — must survive the whole sweep.
        for i in 0..(MAX_RELOCATIONS as u64 - 1) {
            c.note_relocation(RequestId(1_000 + i), 1);
        }
        assert_eq!(c.replica_of(x), 0, "refreshed re-home outlives its tombstone");
        // One more push evicts x's LIVE entry — oldest surviving fact,
        // forgotten in order — and x resolves back to its partition.
        c.note_relocation(RequestId(999_999_999), 1);
        assert_eq!(c.replica_of(x), 1, "past the cap x resolves to id % n");
        // The map never exceeds the cap.
        assert!(c.relocated.len() <= MAX_RELOCATIONS);
    }

    #[test]
    fn failover_migration_beats_recompute_and_reports_counters() {
        // ISSUE-8 acceptance (a), long-prefix half: killing a session's
        // home with migration enabled must make the victim's next turn
        // strictly faster than the recompute path — the chain is shipped
        // to the survivor (rebuilt from the host-recoverable checkpoint,
        // DESIGN.md §18) at a modeled transfer cost instead of being
        // re-prefilled from token zero.
        let run = |migrate: bool| {
            let mut c = session_cluster(migrate);
            let mut mgr = crate::session::SessionManager::new();
            let sid = mgr.create(0);
            let t1 = mgr
                .run_turn(&mut c, sid, ModelTarget::Base, (0..2048).collect(), 16, true)
                .unwrap();
            assert_eq!(t1.cached_tokens, 0);
            let home = (mgr.get(sid).unwrap().last_request.unwrap().0 % 2) as usize;
            let report = c.fail_replica(home).unwrap();
            assert_eq!(report.orphaned_leases, vec![sid.0]);
            mgr.repair_after_failover(&mut c, &report);
            let t2 = mgr
                .run_turn(&mut c, sid, ModelTarget::Base, (3000..3032).collect(), 16, true)
                .unwrap();
            let survivor = 1 - home;
            let committed: Vec<u64> = (0..2)
                .map(|i| c.replica(i).routing_summary().committed_blocks())
                .collect();
            c.replica(survivor).check_invariants().unwrap();
            let stats = c.router().stats.clone();
            let json = c.stats().to_json().to_string();
            mgr.delete(&mut c, sid).unwrap();
            (t2.ttft_s, t2.cached_tokens, committed, stats, json, home)
        };
        let (ttft_m, cached_m, committed_m, stats_m, json_m, home_m) = run(true);
        let (ttft_r, cached_r, committed_r, stats_r, _, home_r) = run(false);
        assert_eq!(home_m, home_r, "deterministic placement across arms");
        assert!(cached_m >= 2048, "migrated chain lands warm: {cached_m}");
        assert_eq!(cached_r, 0, "recompute path starts cold");
        assert!(
            ttft_m < ttft_r,
            "migration must beat recompute: {ttft_m} vs {ttft_r}"
        );
        assert_eq!(stats_m.migrations, 1);
        assert_eq!(stats_m.migrated_blocks, 129, "2064-token chain = 129 blocks");
        assert_eq!(stats_m.migration_recompute_fallbacks, 0);
        assert_eq!(stats_r.migrations, 0);
        // ISSUE-8 satellite: fleet-wide summary totals match the
        // fresh-prefill run — migration commits exactly the hashes a
        // recompute would have, nothing extra, nothing missing.
        assert_eq!(committed_m, committed_r, "summary symmetry after migration");
        // Counters surface in the fleet document, not just Prometheus.
        assert!(json_m.contains("\"migrations\":1"), "{json_m}");
        assert!(json_m.contains("\"migrated_blocks\":129"), "{json_m}");
        assert!(json_m.contains("\"migration_recompute_fallbacks\":0"), "{json_m}");
        assert!(json_m.contains("\"session_forks\":0"), "{json_m}");
    }

    #[test]
    fn failover_migration_short_prefix_recomputes_bit_identically() {
        // ISSUE-8 acceptance (a), short-prefix half: below the cost-model
        // crossover the fixed transfer setup loses to a short prefill, so
        // the fallback must leave the serving path bit-identical to a
        // fleet with migration disabled — same cold turn, same TTFT, same
        // clock — with only the fallback counter recording the decline.
        let run = |migrate: bool| {
            let mut c = session_cluster(migrate);
            let mut mgr = crate::session::SessionManager::new();
            let sid = mgr.create(0);
            mgr.run_turn(&mut c, sid, ModelTarget::Base, (0..64).collect(), 16, true)
                .unwrap();
            let home = (mgr.get(sid).unwrap().last_request.unwrap().0 % 2) as usize;
            let report = c.fail_replica(home).unwrap();
            mgr.repair_after_failover(&mut c, &report);
            let t2 = mgr
                .run_turn(&mut c, sid, ModelTarget::Base, (900..932).collect(), 16, true)
                .unwrap();
            let stats = c.router().stats.clone();
            let clock = c.clock();
            mgr.delete(&mut c, sid).unwrap();
            (t2.ttft_s, t2.cached_tokens, clock, stats)
        };
        let (ttft_m, cached_m, clock_m, stats_m) = run(true);
        let (ttft_r, cached_r, clock_r, stats_r) = run(false);
        assert_eq!(cached_m, 0, "short chain recomputes");
        assert_eq!(cached_r, 0);
        assert_eq!(ttft_m, ttft_r, "declined migration must not perturb the sim");
        assert_eq!(clock_m, clock_r);
        assert_eq!(stats_m.migrations, 0);
        assert_eq!(stats_m.migrated_blocks, 0);
        assert_eq!(stats_m.migration_recompute_fallbacks, 1);
        assert_eq!(stats_r.migration_recompute_fallbacks, 0);
    }

    #[test]
    fn drain_migration_ships_lease_and_keeps_summaries_symmetric() {
        // Drain path: the old home still holds the pinned chain (planned
        // maintenance loses nothing), so migration does a live transfer —
        // the re-stuck turn lands warm on the new home while the lease
        // moves with it. Without the flag this is the pinned recompute
        // behavior of `sticky_turn_to_draining_replica_resticks_via_policy`.
        let run = |migrate: bool| {
            let mut c = session_cluster(migrate);
            let mut mgr = crate::session::SessionManager::new();
            let sid = mgr.create(0);
            mgr.run_turn(&mut c, sid, ModelTarget::Base, (0..2048).collect(), 16, true)
                .unwrap();
            let home = (mgr.get(sid).unwrap().last_request.unwrap().0 % 2) as usize;
            c.drain_replica(home).unwrap();
            let t2 = mgr
                .run_turn(&mut c, sid, ModelTarget::Base, (900..932).collect(), 16, true)
                .unwrap();
            let healthy = 1 - home;
            let leased =
                (c.replica(home).leased_blocks(), c.replica(healthy).leased_blocks());
            let committed: Vec<u64> = (0..2)
                .map(|i| c.replica(i).routing_summary().committed_blocks())
                .collect();
            c.replica(home).check_invariants().unwrap();
            c.replica(healthy).check_invariants().unwrap();
            let stats = c.router().stats.clone();
            mgr.delete(&mut c, sid).unwrap();
            (t2.cached_tokens, t2.ttft_s, leased, committed, stats)
        };
        let (cached_m, ttft_m, leased_m, committed_m, stats_m) = run(true);
        let (cached_r, ttft_r, leased_r, committed_r, stats_r) = run(false);
        assert!(cached_m >= 2048, "drained home's chain shipped warm: {cached_m}");
        assert_eq!(cached_r, 0, "without the flag the turn recomputes cold");
        assert!(ttft_m < ttft_r, "live transfer beats recompute");
        assert_eq!(leased_m.0, 0, "source pin released by the migration");
        assert!(leased_m.1 > 0, "destination pins the shipped chain");
        assert_eq!(leased_m, leased_r, "final lease placement identical either way");
        assert_eq!(stats_m.migrations, 1);
        assert_eq!(stats_m.resticks, 1);
        assert_eq!(stats_r.migrations, 0);
        // Summary symmetry on BOTH replicas: the drained source keeps its
        // unpinned committed copy in each arm, the destination ends up
        // with the same committed set whether installed or recomputed.
        assert_eq!(committed_m, committed_r, "fleet summaries symmetric");
    }
}
