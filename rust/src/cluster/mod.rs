//! Horizontal scale-out: N engine replicas behind a cache-affinity router.
//!
//! A [`Cluster`] owns N independent [`Engine`] replicas and implements the
//! same [`EngineDriver`] interface a single engine does, so the
//! coordinator, the pipeline drivers and the HTTP server drive a fleet
//! without knowing it. Placement is the [`Router`]'s job; the interesting
//! policy is [`RoutePolicy::PrefixAffinity`]: it computes the request's
//! base-aligned block-hash chain once (the identical replica-independent
//! hashes admission uses, `kvcache::prefix`), scores each replica's
//! committed-hash summary ([`crate::kvcache::summary::HashSummary`], fed
//! by commit/eviction events) against that chain, and places the request
//! where its prefix is already resident — so the paper's cross-model KV
//! reuse survives scale-out. Conversation follow-ups submitted by the
//! coordinator inherit their parent's replica automatically: the child's
//! chain extends the parent's, and only the parent's replica scores > 0.
//!
//! Virtual time: replicas run in parallel, so the cluster clock is the max
//! over replica clocks (fleet makespan). Stepping advances every replica
//! with work by one batch; an idle replica's clock is synced forward when
//! a request is routed to it (it genuinely sat idle that long).
//!
//! Request ids are fleet-unique by construction: replica i issues ids
//! `i, i+n, i+2n, ...` (see [`Engine::set_id_namespace`]), so finished
//! outputs flow back through the uniform interface untranslated.
//!
//! Replicas are not assumed immortal: [`Cluster::fail_replica`] /
//! [`Cluster::drain_replica`] / [`Cluster::restore_replica`] move them
//! through [`ReplicaHealth`] states. The router excludes everything but
//! `Up`; failing a replica evacuates its queued work and requeues it
//! onto survivors under the SAME ids (continuation priority) while its
//! leases orphan and its cache is wiped (restore = cold start). The
//! [`FailoverReport`] hands the serving layer what it needs to repair
//! affected sessions (DESIGN.md §15).

pub mod autoscaler;
pub mod health;
pub mod router;

pub use autoscaler::{Autoscaler, ScaleDecision, ScaleSignals};
pub use health::{Beat, HealthMonitor, HealthState, Transition};
pub use router::{Placement, PlacementKind, ReplicaView, RoutePolicy, Router, RouterConfig};

use crate::adapter::AdapterRegistry;
use crate::config::{EngineConfig, FleetConfig};
use crate::engine::{Engine, EngineDriver, EvacuatedRequest, Executor};
use crate::kvcache::block::BlockHash;
use crate::kvcache::chain::ChainRef;
use crate::kvcache::prefix::{block_hashes, HashContext};
use crate::kvcache::summary::HashSummary;
use crate::metrics::{Metrics, RoutingMetrics};
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams, TurnEvent};
use crate::simulator::CostModel;
use crate::util::fxmap::FxHashMap;
use crate::util::json::Json;

/// One replica's serving state. Routing excludes everything but `Up`;
/// the difference between the other two is what happens to work already
/// on the replica: `Draining` finishes it (planned maintenance), `Down`
/// lost it (the failover path evacuated and requeued it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaHealth {
    Up,
    Draining,
    Down,
    /// Pre-provisioned but inactive (DESIGN.md §19): the engine exists —
    /// so request-id striping is fixed at construction for the MAXIMUM
    /// fleet size — but it neither routes, steps, nor heartbeats until
    /// the autoscaler activates it.
    Standby,
}

impl ReplicaHealth {
    pub fn name(&self) -> &'static str {
        match self {
            ReplicaHealth::Up => "up",
            ReplicaHealth::Draining => "draining",
            ReplicaHealth::Down => "down",
            ReplicaHealth::Standby => "standby",
        }
    }
}

/// What one `fail_replica` did — the serving layer feeds this to
/// [`crate::session::SessionManager::repair_after_failover`] so sessions
/// whose state died with the replica recover transparently.
#[derive(Debug, Clone)]
pub struct FailoverReport {
    pub replica: usize,
    pub num_replicas: usize,
    /// Requests requeued onto survivors (same fleet-unique ids).
    pub requeued: usize,
    /// Lease keys (session ids) whose pinned prefix died with the replica.
    pub orphaned_leases: Vec<u64>,
    /// Evacuated requests no survivor would accept — dropped; they will
    /// never produce an output, so their sessions' turns must be aborted.
    pub rejected: Vec<RequestId>,
    /// Ids that moved to a survivor (subset bookkeeping for `strands`).
    pub relocated: Vec<RequestId>,
}

impl FailoverReport {
    /// Did this request's home — its output, its committed blocks — die
    /// with the failed replica? True for ids constructed on the victim
    /// and not relocated by THIS failover. (An id re-homed by an earlier
    /// failover can answer true conservatively; the only cost is one
    /// policy-routed — i.e. cold-capable — turn.)
    pub fn strands(&self, id: RequestId) -> bool {
        (id.0 % self.num_replicas as u64) as usize == self.replica
            && !self.relocated.contains(&id)
    }
}

/// Cap on remembered failover re-homes. The map cannot be pruned
/// precisely (a session's stickiness peer may be consulted long after
/// its output drained), so it is bounded FIFO instead: past the cap the
/// OLDEST re-home is forgotten and that id resolves back to its `id % n`
/// partition — for stickiness the health check degrades that to one
/// policy-routed (possibly cold) turn. Re-relocation refreshes an id's
/// age, so forgetting a STILL-RUNNING request's re-home would take 4096
/// newer requeues landing within its lifetime. Refreshing is O(1): the
/// id re-enters the order queue under a fresh epoch stamp and its old
/// entry stays behind as a tombstone, skipped (not acted on) when it
/// reaches the front — a tombstone transiently dilutes the effective
/// capacity by one slot until it drains, which only trims the grace
/// window, never evicts out of order.
const MAX_RELOCATIONS: usize = 4096;

pub struct Cluster<E: Executor> {
    replicas: Vec<Engine<E>>,
    router: Router,
    /// Per-replica serving state; routing only sees `Up` replicas.
    health: Vec<ReplicaHealth>,
    /// Failover re-homes: request id → (replica it was requeued onto,
    /// epoch of that re-home). Overrides the construction-time `id % n`
    /// mapping for stickiness, leases, and event routing. Bounded by
    /// [`MAX_RELOCATIONS`] (FIFO, `relocation_order`); the epoch lets
    /// eviction tell a live entry from a tombstone left by re-relocation.
    relocated: FxHashMap<RequestId, (usize, u64)>,
    /// Insertion order of `relocated` entries, stamped with the epoch of
    /// the insertion (front = oldest = first forgotten past the cap; an
    /// entry whose stamp no longer matches the map's is a tombstone and
    /// is skipped).
    relocation_order: std::collections::VecDeque<(RequestId, u64)>,
    /// Monotone stamp source for `relocation_order` entries.
    relocation_epoch: u64,
    /// Fleet-level registry: the coordinator's per-stage series land here;
    /// `/metrics` renders this merged with every replica's counters.
    metrics: Metrics,
    /// Self-driving knobs (DESIGN.md §19). The default config makes every
    /// control path below a strict no-op: live summaries, no autoscaler,
    /// and a monitor that only matters once a replica is silenced.
    fleet: FleetConfig,
    /// Heartbeat failure detector, fed one beat vector per fleet step.
    monitor: HealthMonitor,
    /// Scale decision controller; consulted only with `fleet.autoscale`.
    autoscaler: Autoscaler,
    /// Fault injection: a silenced replica keeps its state and keeps
    /// stepping (a network partition, not a crash) but stops delivering
    /// heartbeats and gossip until `restore_replica`.
    silenced: Vec<bool>,
    /// Freshly activated replicas take only overflow placements until
    /// their (gossiped) summary holds `fleet.warmup_min_blocks` blocks.
    warming: Vec<bool>,
    /// Gossiped routing-summary snapshots: `(summary, round stamp)`.
    /// `None` = nothing gossiped yet (fresh activation / wiped storage).
    /// Probed by `views_for_chain` instead of the live summary whenever
    /// `fleet.gossip_period_steps > 0`.
    gossip: Vec<Option<(HashSummary, u64)>>,
    /// Monotone gossip round counter (stamps snapshots for staleness).
    gossip_round: u64,
    /// Steps since the last gossip round.
    steps_since_gossip: u32,
    /// Failovers run by the detector (not an admin call): the serving
    /// layer drains these via `take_failover_reports` and runs the same
    /// session repair an operator-declared failure gets.
    pending_failovers: Vec<FailoverReport>,
    /// The replica currently draining toward `Standby` under a scale-down
    /// decision; retired (leases batch-migrated) once its work drains.
    descaling: Option<usize>,
}

/// One replica's headline numbers for `GET /cluster`.
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub replica: usize,
    /// Serving state: "up", "draining", "down", or "standby".
    pub health: &'static str,
    /// Finer-grained serving state for dashboards:
    /// `up | suspected(n) | warming | draining | down | standby`.
    pub health_detail: String,
    pub clock: f64,
    pub running: usize,
    pub waiting: usize,
    pub finished: u64,
    pub free_blocks: u32,
    pub total_blocks: u32,
    /// Committed (routable) blocks in this replica's summary.
    pub committed_blocks: u64,
    pub hit_rate: f64,
    pub routed: u64,
    /// Adapter ids resident on this replica (ascending; empty with
    /// adapter paging off — everything is implicitly resident then).
    pub resident_adapters: Vec<u32>,
    /// Blocks charged to those adapters' weights.
    pub adapter_resident_blocks: usize,
    pub adapter_loads: u64,
    pub adapter_evictions: u64,
    /// Modeled host-tier capacity in blocks (0 = no host tier;
    /// DESIGN.md §20). Per-replica: heterogeneous fleets differ here.
    pub host_total_blocks: u64,
    /// Adapter blocks currently demoted to (parked on) the host tier.
    pub adapter_host_blocks: usize,
    pub adapter_demotions: u64,
    pub adapter_promotions: u64,
    pub adapter_host_drops: u64,
    pub adapter_prefetches: u64,
}

/// The per-replica engine configuration summary `GET /cluster` reports so
/// fleet dashboards don't need out-of-band config (replicas are identical
/// by construction, so one summary describes them all).
#[derive(Debug, Clone)]
pub struct ReplicaConfigSummary {
    pub model: String,
    pub block_size: u32,
    /// Device budget per replica in blocks (KV + adapter weights).
    pub total_blocks: u64,
    pub max_batch_tokens: u32,
    pub max_num_seqs: u32,
    pub admission_watermark: f64,
    pub base_aligned_hashing: bool,
    pub adapter_paging: bool,
}

/// Self-driving control-loop snapshot for `GET /cluster` (DESIGN.md §19).
#[derive(Debug, Clone)]
pub struct FleetStats {
    pub autoscale: bool,
    /// Routable (`Up`) replicas, warming ones included.
    pub active_replicas: usize,
    pub standby_replicas: usize,
    pub cooldown_remaining: u32,
    pub high_streak: u32,
    pub low_streak: u32,
    pub gossip_period_steps: u32,
    pub gossip_round: u64,
    /// Replica currently draining toward standby under a scale-down.
    pub descaling: Option<usize>,
}

impl FleetStats {
    /// The shape a fleet of one (or a disabled controller) reports.
    pub fn single() -> Self {
        FleetStats {
            autoscale: false,
            active_replicas: 1,
            standby_replicas: 0,
            cooldown_remaining: 0,
            high_streak: 0,
            low_streak: 0,
            gossip_period_steps: 0,
            gossip_round: 0,
            descaling: None,
        }
    }
}

/// Fleet snapshot for `GET /cluster` and tests.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    /// Active router policy name.
    pub policy: &'static str,
    pub config: ReplicaConfigSummary,
    pub replicas: Vec<ReplicaStats>,
    pub routing: RoutingMetrics,
    pub fleet: FleetStats,
    /// Token-weighted prefix hit rate across the fleet.
    pub aggregate_hit_rate: f64,
    /// Fleet fraction of adapter admissions that found weights resident.
    pub aggregate_adapter_hit_rate: f64,
}

impl ClusterStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::str(self.policy)),
            (
                "config",
                Json::obj(vec![
                    ("model", Json::str(self.config.model.clone())),
                    ("block_size", Json::num(self.config.block_size as f64)),
                    ("total_blocks", Json::num(self.config.total_blocks as f64)),
                    ("max_batch_tokens", Json::num(self.config.max_batch_tokens as f64)),
                    ("max_num_seqs", Json::num(self.config.max_num_seqs as f64)),
                    (
                        "admission_watermark",
                        Json::num(self.config.admission_watermark),
                    ),
                    (
                        "base_aligned_hashing",
                        Json::Bool(self.config.base_aligned_hashing),
                    ),
                    ("adapter_paging", Json::Bool(self.config.adapter_paging)),
                ]),
            ),
            ("aggregate_hit_rate", Json::num(self.aggregate_hit_rate)),
            (
                "aggregate_adapter_hit_rate",
                Json::num(self.aggregate_adapter_hit_rate),
            ),
            (
                "routing",
                Json::obj(vec![
                    (
                        "routed",
                        Json::Arr(
                            self.routing.routed.iter().map(|&n| Json::num(n as f64)).collect(),
                        ),
                    ),
                    ("affinity_hits", Json::num(self.routing.affinity_hits as f64)),
                    ("affinity_fallbacks", Json::num(self.routing.affinity_fallbacks as f64)),
                    ("sticky_routed", Json::num(self.routing.sticky_routed as f64)),
                    ("replica_failures", Json::num(self.routing.replica_failures as f64)),
                    ("requeued_requests", Json::num(self.routing.requeued_requests as f64)),
                    ("orphaned_leases", Json::num(self.routing.orphaned_leases as f64)),
                    ("resticks", Json::num(self.routing.resticks as f64)),
                    ("migrations", Json::num(self.routing.migrations as f64)),
                    ("migrated_blocks", Json::num(self.routing.migrated_blocks as f64)),
                    (
                        "migration_recompute_fallbacks",
                        Json::num(self.routing.migration_recompute_fallbacks as f64),
                    ),
                    ("session_forks", Json::num(self.routing.session_forks as f64)),
                    (
                        "heartbeat_misses",
                        Json::num(self.routing.heartbeat_misses as f64),
                    ),
                    (
                        "suspected_transitions",
                        Json::num(self.routing.suspected_transitions as f64),
                    ),
                    (
                        "detected_failures",
                        Json::num(self.routing.detected_failures as f64),
                    ),
                    ("scale_ups", Json::num(self.routing.scale_ups as f64)),
                    ("scale_downs", Json::num(self.routing.scale_downs as f64)),
                    (
                        "stale_sketch_decays",
                        Json::num(self.routing.stale_sketch_decays as f64),
                    ),
                    ("imbalance", Json::num(self.routing.imbalance())),
                ]),
            ),
            (
                "fleet",
                Json::obj(vec![
                    ("autoscale", Json::Bool(self.fleet.autoscale)),
                    (
                        "active_replicas",
                        Json::num(self.fleet.active_replicas as f64),
                    ),
                    (
                        "standby_replicas",
                        Json::num(self.fleet.standby_replicas as f64),
                    ),
                    (
                        "cooldown_remaining",
                        Json::num(self.fleet.cooldown_remaining as f64),
                    ),
                    ("high_streak", Json::num(self.fleet.high_streak as f64)),
                    ("low_streak", Json::num(self.fleet.low_streak as f64)),
                    (
                        "gossip_period_steps",
                        Json::num(self.fleet.gossip_period_steps as f64),
                    ),
                    ("gossip_round", Json::num(self.fleet.gossip_round as f64)),
                    (
                        "descaling",
                        match self.fleet.descaling {
                            Some(i) => Json::num(i as f64),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
            (
                "replicas",
                Json::Arr(
                    self.replicas
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("replica", Json::num(r.replica as f64)),
                                ("health", Json::str(r.health)),
                                ("health_detail", Json::str(r.health_detail.clone())),
                                ("clock_s", Json::num(r.clock)),
                                ("running", Json::num(r.running as f64)),
                                ("waiting", Json::num(r.waiting as f64)),
                                ("finished", Json::num(r.finished as f64)),
                                ("free_blocks", Json::num(r.free_blocks as f64)),
                                ("total_blocks", Json::num(r.total_blocks as f64)),
                                ("committed_blocks", Json::num(r.committed_blocks as f64)),
                                ("cache_hit_rate", Json::num(r.hit_rate)),
                                ("routed", Json::num(r.routed as f64)),
                                (
                                    "resident_adapters",
                                    Json::Arr(
                                        r.resident_adapters
                                            .iter()
                                            .map(|&a| Json::num(a as f64))
                                            .collect(),
                                    ),
                                ),
                                (
                                    "adapter_resident_blocks",
                                    Json::num(r.adapter_resident_blocks as f64),
                                ),
                                ("adapter_loads", Json::num(r.adapter_loads as f64)),
                                (
                                    "adapter_evictions",
                                    Json::num(r.adapter_evictions as f64),
                                ),
                                (
                                    "host_total_blocks",
                                    Json::num(r.host_total_blocks as f64),
                                ),
                                (
                                    "adapter_host_blocks",
                                    Json::num(r.adapter_host_blocks as f64),
                                ),
                                (
                                    "adapter_demotions",
                                    Json::num(r.adapter_demotions as f64),
                                ),
                                (
                                    "adapter_promotions",
                                    Json::num(r.adapter_promotions as f64),
                                ),
                                (
                                    "adapter_host_drops",
                                    Json::num(r.adapter_host_drops as f64),
                                ),
                                (
                                    "adapter_prefetches",
                                    Json::num(r.adapter_prefetches as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl<E: Executor> Cluster<E> {
    /// Wrap pre-built replicas. They must share cache geometry (the
    /// affinity chain is hashed once with one block size) and must not
    /// have served traffic yet (id namespacing).
    pub fn new(replicas: Vec<Engine<E>>, policy: RoutePolicy) -> anyhow::Result<Self> {
        Self::with_config(replicas, RouterConfig { policy, ..Default::default() })
    }

    pub fn with_config(
        mut replicas: Vec<Engine<E>>,
        rcfg: RouterConfig,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!replicas.is_empty(), "cluster needs at least one replica");
        let n = replicas.len();
        // Routing hashes the chain once with replica 0's config/registry
        // and config()/registry() report replica 0's — so replicas must
        // genuinely be identical, not merely block-size-compatible
        // (a base_aligned_hashing or adapter mismatch would silently
        // zero the affinity scores on the divergent replicas). The ONLY
        // tolerated divergence is capacity (DESIGN.md §20): per-replica
        // device budget and host-tier size never enter hashing or token
        // accounting, so heterogeneous fleets stay routable.
        let normalized = |r: &Engine<E>| {
            let mut cfg = r.cfg.clone();
            cfg.cache.max_kv_tokens = 0;
            cfg.cache.host_adapter_blocks = 0;
            cfg
        };
        let reference = normalized(&replicas[0]);
        for (i, r) in replicas.iter().enumerate() {
            anyhow::ensure!(
                r.is_fresh(),
                "replica {i} has already served traffic (clusters wrap fresh engines)"
            );
            anyhow::ensure!(
                normalized(r) == reference,
                "replica {i} config differs from replica 0 beyond capacity"
            );
            anyhow::ensure!(
                r.registry.iter().eq(replicas[0].registry.iter()),
                "replica {i} adapter registry differs from replica 0"
            );
        }
        for (i, r) in replicas.iter_mut().enumerate() {
            r.set_id_namespace(i as u64, n as u64);
        }
        let router = Router::new(rcfg, n);
        let fleet = FleetConfig::default();
        let monitor = HealthMonitor::new(n, &fleet);
        let autoscaler = Autoscaler::new(fleet.clone());
        Ok(Cluster {
            replicas,
            router,
            health: vec![ReplicaHealth::Up; n],
            relocated: FxHashMap::default(),
            relocation_order: std::collections::VecDeque::new(),
            relocation_epoch: 0,
            metrics: Metrics::new(),
            fleet,
            monitor,
            autoscaler,
            silenced: vec![false; n],
            warming: vec![false; n],
            gossip: vec![None; n],
            gossip_round: 0,
            steps_since_gossip: 0,
            pending_failovers: Vec::new(),
            descaling: None,
        })
    }

    /// Build `n` identical replicas from a factory.
    pub fn from_factory(
        n: usize,
        policy: RoutePolicy,
        mut f: impl FnMut(usize) -> Engine<E>,
    ) -> anyhow::Result<Self> {
        Self::new((0..n).map(&mut f).collect(), policy)
    }

    /// Build a (possibly heterogeneous) fleet from a base config and
    /// `fleet.replica_specs` (DESIGN.md §20): replica `i` runs the base
    /// config with spec `i` applied — differing device budget and
    /// host-tier size only, so routing's shared chain hashing still
    /// holds. Specs shorter than the fleet leave the tail on the base;
    /// an empty spec list reproduces `with_fleet` on identical replicas
    /// exactly. The factory receives the replica's specialized config.
    pub fn from_specs(
        n: usize,
        base: &EngineConfig,
        rcfg: RouterConfig,
        fleet: FleetConfig,
        initial_active: usize,
        mut f: impl FnMut(usize, EngineConfig) -> Engine<E>,
    ) -> anyhow::Result<Self> {
        let replicas = (0..n)
            .map(|i| {
                let mut cfg = base.clone();
                if let Some(spec) = fleet.replica_specs.get(i) {
                    spec.apply(&mut cfg);
                }
                f(i, cfg)
            })
            .collect();
        Self::with_fleet(replicas, rcfg, fleet, initial_active)
    }

    /// A self-driving fleet (DESIGN.md §19): `replicas.len()` is the
    /// MAXIMUM size — request-id striping is fixed to it forever — and
    /// replicas past `initial_active` start as [`ReplicaHealth::Standby`]
    /// for the autoscaler to activate under sustained pressure.
    pub fn with_fleet(
        replicas: Vec<Engine<E>>,
        rcfg: RouterConfig,
        fleet: FleetConfig,
        initial_active: usize,
    ) -> anyhow::Result<Self> {
        fleet.validate()?;
        anyhow::ensure!(
            (1..=replicas.len()).contains(&initial_active),
            "initial_active must be in 1..={} (the pre-provisioned maximum)",
            replicas.len()
        );
        anyhow::ensure!(
            fleet.min_replicas <= replicas.len(),
            "min_replicas {} exceeds the pre-provisioned maximum {}",
            fleet.min_replicas,
            replicas.len()
        );
        let mut c = Self::with_config(replicas, rcfg)?;
        for i in initial_active..c.replicas.len() {
            c.health[i] = ReplicaHealth::Standby;
        }
        c.set_fleet_config(fleet)?;
        Ok(c)
    }

    /// Swap in a validated [`FleetConfig`], rebuilding the monitor and the
    /// autoscaler against it. Replicas already declared `Down` stay
    /// declared (the fresh monitor is pinned to agree with the health
    /// table, so it never re-fires their failover).
    pub fn set_fleet_config(&mut self, fleet: FleetConfig) -> anyhow::Result<()> {
        fleet.validate()?;
        let n = self.replicas.len();
        self.monitor = HealthMonitor::new(n, &fleet);
        for i in 0..n {
            if self.health[i] == ReplicaHealth::Down {
                self.monitor.mark_down(i);
            }
        }
        self.autoscaler = Autoscaler::new(fleet.clone());
        self.steps_since_gossip = 0;
        self.fleet = fleet;
        Ok(())
    }

    pub fn fleet_config(&self) -> &FleetConfig {
        &self.fleet
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn replica(&self, i: usize) -> &Engine<E> {
        &self.replicas[i]
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn health(&self, i: usize) -> ReplicaHealth {
        self.health[i]
    }

    /// Replicas accepting new placements.
    pub fn num_healthy(&self) -> usize {
        self.health.iter().filter(|h| **h == ReplicaHealth::Up).count()
    }

    /// Pre-provisioned replicas the autoscaler could still activate.
    pub fn num_standby(&self) -> usize {
        self.health.iter().filter(|h| **h == ReplicaHealth::Standby).count()
    }

    /// Is replica `i` routing-penalized by the failure detector? True
    /// only for an `Up` replica inside the monitor's suspected band —
    /// the penalty is the router's job (see `ReplicaView::suspected`).
    pub fn is_suspected(&self, i: usize) -> bool {
        self.health[i] == ReplicaHealth::Up
            && matches!(self.monitor.state(i), HealthState::Suspected(_))
    }

    /// The `health_detail` string for replica `i`:
    /// `up | suspected(n) | warming | draining | down | standby`.
    pub fn health_detail(&self, i: usize) -> String {
        match self.health[i] {
            ReplicaHealth::Down => "down".to_string(),
            ReplicaHealth::Draining => "draining".to_string(),
            ReplicaHealth::Standby => "standby".to_string(),
            ReplicaHealth::Up if self.is_suspected(i) => self.monitor.state(i).detail(),
            ReplicaHealth::Up if self.warming[i] => "warming".to_string(),
            ReplicaHealth::Up => "up".to_string(),
        }
    }

    /// Fault injection (DESIGN.md §19): replica `i` stops delivering
    /// heartbeats and gossip while keeping its state and its work — a
    /// network partition, not a crash. The monitor walks it through
    /// `Suspected` into `Down` (which runs the ordinary failover
    /// pipeline) unless `restore_replica` lifts the silence first.
    pub fn silence_replica(&mut self, i: usize) -> anyhow::Result<()> {
        anyhow::ensure!(i < self.replicas.len(), "no replica {i}");
        anyhow::ensure!(
            matches!(self.health[i], ReplicaHealth::Up | ReplicaHealth::Draining),
            "replica {i} is {} (only an up or draining replica can be silenced)",
            self.health[i].name()
        );
        self.silenced[i] = true;
        Ok(())
    }

    /// Detector-initiated failovers not yet repaired by the serving
    /// layer. Drained once per server step; each report gets the same
    /// session repair an operator-declared failure gets.
    pub fn take_failover_reports(&mut self) -> Vec<FailoverReport> {
        std::mem::take(&mut self.pending_failovers)
    }

    /// The replica holding `id`'s state: its failover re-home if it was
    /// requeued, else the construction-time partition (`id % n`).
    fn replica_of(&self, id: RequestId) -> usize {
        self.relocated
            .get(&id)
            .map(|&(ri, _)| ri)
            .unwrap_or((id.0 % self.replicas.len() as u64) as usize)
    }

    /// Mark replica `i` failed: its queued work is evacuated and requeued
    /// onto healthy survivors (same fleet-unique ids, continuation
    /// priority — callers blocked on a `RequestId` still get their
    /// output), its leases are orphaned, and its cache is wiped (a later
    /// [`Self::restore_replica`] starts cold). Finished-but-undrained
    /// outputs survive: the completion ledger is serving-layer state, not
    /// device memory. Refuses to take down the last healthy replica —
    /// there would be no survivor to requeue onto.
    pub fn fail_replica(&mut self, i: usize) -> anyhow::Result<FailoverReport> {
        anyhow::ensure!(i < self.replicas.len(), "no replica {i}");
        anyhow::ensure!(
            self.health[i] != ReplicaHealth::Down,
            "replica {i} is already down"
        );
        let survivors = (0..self.replicas.len())
            .filter(|&j| j != i && self.health[j] == ReplicaHealth::Up)
            .count();
        anyhow::ensure!(
            survivors > 0,
            "cannot fail replica {i}: no healthy survivor to requeue onto"
        );
        self.health[i] = ReplicaHealth::Down;
        // Pin the monitor to agree: a silenced replica the operator (or
        // the detector itself) declared dead must never fire a SECOND
        // failover when its misses keep accruing.
        self.monitor.mark_down(i);
        self.gossip[i] = None;
        self.warming[i] = false;
        if self.descaling == Some(i) {
            self.descaling = None;
        }
        self.router.stats.replica_failures += 1;
        let evacuated = self.replicas[i].evacuate_requests();
        let orphaned_leases = self.replicas[i].fail_storage();
        self.router.stats.orphaned_leases += orphaned_leases.len() as u64;
        let mut report = FailoverReport {
            replica: i,
            num_replicas: self.replicas.len(),
            requeued: 0,
            orphaned_leases,
            rejected: Vec::new(),
            relocated: Vec::new(),
        };
        // Reverse order: requeued requests enqueue with continuation
        // priority (push-front), so per survivor the LAST submission ends
        // up first — reversing the FCFS evacuation order here restores it
        // on every survivor's queue.
        for ev in evacuated.into_iter().rev() {
            let id = ev.id;
            match self.requeue(ev) {
                Ok(ri) => {
                    report.requeued += 1;
                    report.relocated.push(id);
                    self.note_relocation(id, ri);
                }
                Err(ev) => {
                    // Nobody took it: the request is lost — but it WAS
                    // received, so re-credit the victim's rolled-back
                    // counters (evacuation assumed a survivor would
                    // re-count them) to keep the fleet aggregate at
                    // exactly one per request.
                    let r = &mut self.replicas[i];
                    r.metrics.requests_received += 1;
                    r.metrics.prompt_tokens += ev.prompt.len() as u64;
                    report.rejected.push(id);
                }
            }
        }
        Ok(report)
    }

    /// Record a failover re-home, evicting the oldest LIVE entry past the
    /// cap (see [`MAX_RELOCATIONS`] for the degradation semantics). A
    /// re-relocated id (its survivor failed too) re-enters the order at
    /// the BACK under a fresh epoch stamp — its freshest re-home is also
    /// its freshest fact, and must not be the first forgotten. The stale
    /// front entry becomes a tombstone (its stamp no longer matches the
    /// map's) and is skipped at eviction time, so re-relocation is O(1)
    /// instead of an O(n) scan of the order queue — under a mass requeue
    /// (a replica failing with thousands of re-homed requests aboard,
    /// every one of them re-relocating) the old `retain` walk made each
    /// re-home cost the whole window.
    fn note_relocation(&mut self, id: RequestId, ri: usize) {
        self.relocation_epoch += 1;
        let epoch = self.relocation_epoch;
        self.relocated.insert(id, (ri, epoch));
        self.relocation_order.push_back((id, epoch));
        while self.relocation_order.len() > MAX_RELOCATIONS {
            if let Some((old, stamp)) = self.relocation_order.pop_front() {
                let live =
                    self.relocated.get(&old).map(|&(_, cur)| cur == stamp).unwrap_or(false);
                if live {
                    self.relocated.remove(&old);
                }
            }
        }
    }

    /// Route one evacuated request onto a healthy survivor, trying the
    /// router's pick first and every other healthy replica after it (an
    /// identically-configured survivor re-accepts anything it admitted
    /// before, so fallbacks only matter for exotic third-party states).
    /// Err returns the request when nobody took it (the caller reports
    /// it rejected and re-credits the victim's counters).
    fn requeue(&mut self, ev: EvacuatedRequest) -> Result<usize, EvacuatedRequest> {
        let (views, chain) = self.views_for(ev.target, &ev.prompt, ev.cache_salt);
        let placement = self.router.choose(&views);
        let now = self.clock();
        let mut order = vec![placement.replica];
        order.extend(
            (0..self.replicas.len())
                .filter(|&j| j != placement.replica && self.health[j] == ReplicaHealth::Up),
        );
        for (attempt, &ri) in order.iter().enumerate() {
            let r = &mut self.replicas[ri];
            if !r.has_work() && r.clock() < now {
                r.advance_clock_to(now);
            }
            if r.submit_evacuated(ev.clone(), chain.clone()).is_ok() {
                if attempt == 0 {
                    self.router.record(placement);
                } else {
                    self.router.stats.routed[ri] += 1;
                }
                self.router.stats.requeued_requests += 1;
                return Ok(ri);
            }
        }
        Err(ev)
    }

    /// Begin draining replica `i`: the router stops placing new work on
    /// it (sticky turns re-stick through the policy) while its in-flight
    /// and waiting work runs to completion — planned maintenance, nothing
    /// is lost. Refuses to drain the last healthy replica.
    pub fn drain_replica(&mut self, i: usize) -> anyhow::Result<()> {
        anyhow::ensure!(i < self.replicas.len(), "no replica {i}");
        anyhow::ensure!(
            self.health[i] == ReplicaHealth::Up,
            "replica {i} is {} (only an up replica can drain)",
            self.health[i].name()
        );
        anyhow::ensure!(
            self.num_healthy() > 1,
            "cannot drain replica {i}: it is the last healthy replica"
        );
        self.health[i] = ReplicaHealth::Draining;
        Ok(())
    }

    /// Bring replica `i` back into rotation. A previously failed replica
    /// returns cold (its cache was wiped at failure); a drained one
    /// returns exactly as it was. Restoring also lifts any silence and
    /// re-arms the failure detector from zero misses — so it applies to
    /// an `Up` replica too when that replica is silenced or suspected
    /// (its beats "resume", it keeps every request and lease it holds).
    pub fn restore_replica(&mut self, i: usize) -> anyhow::Result<()> {
        anyhow::ensure!(i < self.replicas.len(), "no replica {i}");
        anyhow::ensure!(
            self.health[i] != ReplicaHealth::Up
                || self.silenced[i]
                || self.is_suspected(i),
            "replica {i} is already up"
        );
        if self.health[i] == ReplicaHealth::Down {
            // Its storage was wiped at failure; whatever snapshot other
            // replicas hold of it describes blocks that no longer exist.
            self.gossip[i] = None;
        }
        if self.descaling == Some(i) {
            self.descaling = None;
        }
        self.health[i] = ReplicaHealth::Up;
        self.silenced[i] = false;
        self.warming[i] = false;
        self.monitor.reset(i);
        Ok(())
    }

    /// Token-weighted prefix hit rate across the fleet (sums the per-
    /// replica admission counters, so replicas with more traffic weigh
    /// more — the scaling figure's y-axis).
    pub fn aggregate_hit_rate(&self) -> f64 {
        let (mut hit, mut asked) = (0u64, 0u64);
        for r in &self.replicas {
            let s = r.kv_stats();
            hit += s.prefix_tokens_hit;
            asked += s.prefix_tokens_queried;
        }
        if asked == 0 {
            0.0
        } else {
            hit as f64 / asked as f64
        }
    }

    /// Full fleet metrics aggregation — counters summed, latency series
    /// and histograms sample-merged, clock = makespan — for offline
    /// analysis (the scaling figure's fleet latency column). The
    /// `/metrics` scrape path deliberately does NOT use this: merging the
    /// sample vectors is O(requests served).
    pub fn aggregate_metrics(&self) -> Metrics {
        let mut agg = Metrics::new();
        agg.absorb(&self.metrics);
        for r in &self.replicas {
            agg.absorb(&r.metrics);
        }
        agg
    }

    /// Total tokens processed (prompt + generated) across the fleet —
    /// numerator of aggregate throughput over the makespan clock.
    pub fn total_tokens_processed(&self) -> u64 {
        self.replicas
            .iter()
            .map(|r| r.metrics.prompt_tokens + r.metrics.generated_tokens)
            .sum()
    }

    /// Fleet fraction of adapter admissions whose weights were already
    /// resident — what adapter-aware placement optimizes for.
    pub fn aggregate_adapter_hit_rate(&self) -> f64 {
        let (mut hits, mut total) = (0u64, 0u64);
        for r in &self.replicas {
            let s = r.residency().stats();
            hits += s.adapter_admission_hits;
            total += s.adapter_admissions;
        }
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    pub fn stats(&self) -> ClusterStats {
        ClusterStats {
            policy: self.router.policy().name(),
            config: config_summary(&self.replicas[0].cfg),
            replicas: self
                .replicas
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    replica_stats(
                        i,
                        r,
                        self.router.stats.routed[i],
                        self.health[i].name(),
                        self.health_detail(i),
                    )
                })
                .collect(),
            routing: self.router.stats.clone(),
            fleet: FleetStats {
                autoscale: self.fleet.autoscale,
                active_replicas: self.num_healthy(),
                standby_replicas: self.num_standby(),
                cooldown_remaining: self.autoscaler.cooldown_remaining(),
                high_streak: self.autoscaler.high_streak(),
                low_streak: self.autoscaler.low_streak(),
                gossip_period_steps: self.fleet.gossip_period_steps,
                gossip_round: self.gossip_round,
                descaling: self.descaling,
            },
            aggregate_hit_rate: self.aggregate_hit_rate(),
            aggregate_adapter_hit_rate: self.aggregate_adapter_hit_rate(),
        }
    }

    /// The `GET /cluster/health` document (DESIGN.md §19): the failure
    /// detector's view of every replica plus the thresholds it runs on —
    /// what an operator pages on before `GET /cluster`'s full snapshot.
    pub fn health_doc(&self) -> Json {
        Json::obj(vec![
            (
                "suspect_after_misses",
                Json::num(self.fleet.suspect_after_misses as f64),
            ),
            (
                "down_after_misses",
                Json::num(self.fleet.down_after_misses as f64),
            ),
            ("num_healthy", Json::num(self.num_healthy() as f64)),
            ("num_standby", Json::num(self.num_standby() as f64)),
            (
                "detected_failures",
                Json::num(self.router.stats.detected_failures as f64),
            ),
            (
                "replicas",
                Json::Arr(
                    (0..self.replicas.len())
                        .map(|i| {
                            Json::obj(vec![
                                ("replica", Json::num(i as f64)),
                                ("health", Json::str(self.health[i].name())),
                                (
                                    "health_detail",
                                    Json::str(self.health_detail(i)),
                                ),
                                (
                                    "heartbeat_misses",
                                    Json::num(self.monitor.misses(i) as f64),
                                ),
                                ("silenced", Json::Bool(self.silenced[i])),
                                ("warming", Json::Bool(self.warming[i])),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// The salting context a request will hash under — the SAME derivation
    /// `Engine::submit_salted` uses (`AdapterRegistry::request_hash_context`),
    /// so the routing chain is byte-identical to the chain admission will
    /// present. Unknown adapters fall back to the base context; submission
    /// rejects them right after (and the placement goes unrecorded).
    fn routing_context(
        &self,
        target: ModelTarget,
        prompt: &[u32],
        cache_salt: u64,
    ) -> HashContext {
        self.replicas[0]
            .registry
            .request_hash_context(
                target.adapter(),
                prompt,
                self.replicas[0].cfg.cache.base_aligned_hashing,
                cache_salt,
            )
            .map(|(_, ctx)| ctx)
            .unwrap_or_else(|| HashContext { cache_salt, ..HashContext::base() })
    }

    /// Score every replica for one request. The chain is hashed ONCE —
    /// each replica contributes only a summary probe plus an O(1)
    /// residency lookup (no pool walks) — and returned as an interned
    /// [`ChainRef`] so submission can pre-seed the request with it
    /// (admission then skips rehashing the same prompt, and handing the
    /// handle to a replica shares arena nodes instead of copying).
    fn views_for(
        &self,
        target: ModelTarget,
        prompt: &[u32],
        cache_salt: u64,
    ) -> (Vec<ReplicaView>, ChainRef) {
        let chain = if self.router.needs_chain() {
            let ctx = self.routing_context(target, prompt, cache_salt);
            let bs = self.replicas[0].cfg.cache.block_size as usize;
            ChainRef::from_hashes(&block_hashes(prompt, bs, &ctx))
        } else {
            ChainRef::empty()
        };
        let views = self.views_for_chain(target, &chain, None);
        (views, chain)
    }

    /// Score every replica against a pre-hashed chain, cheaply:
    ///
    /// - **Lease hint** — if `lease` names a prefix lease a replica pins,
    ///   that replica's summary maintains the chain's matched run
    ///   incrementally (see `HashSummary::track`), so its affinity is
    ///   read in O(1) (plus a probe per delta block past the tracked
    ///   chain) instead of scanning. The hint is validated in O(delta):
    ///   chains are interned in one arena, so "the tracked chain IS a
    ///   prefix of the query chain" is a parent walk to the tracked
    ///   head plus a node-identity compare — no hash comparison and no
    ///   materialization.
    /// - **Probe watermark** — replicas whose best possible score
    ///   (`chain.len() + adapter_blocks - penalty × load`) cannot beat
    ///   the best score already seen are reported with affinity 0 and
    ///   never probed. The router's decision is provably unchanged: the
    ///   true argmax replica is always probed (its true score exceeds
    ///   the watermark that would have skipped it), skipped replicas'
    ///   reported scores never exceed an earlier probed one (so neither
    ///   the argmax nor its first-index tie-break can flip), and the
    ///   all-reported-zero cold corner falls back to least-loaded, which
    ///   the skip condition guarantees is the same replica the full scan
    ///   would have picked. Unhealthy replicas are never probed at all —
    ///   every policy ignores their affinity.
    fn views_for_chain(
        &self,
        target: ModelTarget,
        chain: &ChainRef,
        lease: Option<u64>,
    ) -> Vec<ReplicaView> {
        let penalty = self.router.load_penalty();
        let mut best = f64::NEG_INFINITY;
        // A cold scan (no usable lease hint on that replica) walks the
        // chain front-to-back, which needs a materialized slice. It is
        // built at most ONCE per placement, lazily — a sticky-warm fleet
        // where every probed replica rides the tracked-chain fast path
        // never pays the copy, and delta turns never reach here at all
        // (they take the sticky no-scan path in `submit_sticky_prehashed`).
        let mut full: Option<Vec<BlockHash>> = None;
        let mut views = Vec::with_capacity(self.replicas.len());
        for (i, r) in self.replicas.iter().enumerate() {
            let load = r.num_running() + r.num_waiting();
            // Adapter-residency term: weight pages this replica would
            // NOT have to load for the request (0 with paging off —
            // then weights are free everywhere and the term vanishes).
            let adapter_blocks = target
                .adapter()
                .map(|aid| r.adapter_affinity_blocks(aid))
                .unwrap_or(0);
            let healthy = self.health[i] == ReplicaHealth::Up;
            // Gossip interposition (DESIGN.md §19): with a nonzero gossip
            // period the router scores the replica's last gossiped
            // snapshot instead of its live summary, scaled down once the
            // snapshot's round stamp falls past the staleness bound. At
            // period 0 this arm is NEVER taken and the probe below reads
            // the live summary through the identical code path — the
            // bit-identity the tests pin.
            let gossiped: Option<(Option<&HashSummary>, f64)> =
                if self.fleet.gossip_period_steps > 0 {
                    Some(match &self.gossip[i] {
                        Some((snap, stamp)) => {
                            let over = self
                                .gossip_round
                                .saturating_sub(*stamp)
                                .saturating_sub(self.fleet.gossip_stale_rounds as u64);
                            let factor = (1.0
                                - self.fleet.gossip_decay_slope * over as f64)
                                .max(0.0);
                            (Some(snap), factor)
                        }
                        // Nothing gossiped yet (fresh activation): no
                        // routable affinity — decay all the way to the
                        // least-loaded fallback.
                        None => (None, 0.0),
                    })
                } else {
                    None
                };
            let affinity_blocks = if chain.is_empty() || !healthy {
                0
            } else {
                let ub = (chain.len() + adapter_blocks) as f64 - penalty * load as f64;
                let (summary, factor) = match &gossiped {
                    Some((snap, factor)) => (*snap, *factor),
                    None => (Some(r.routing_summary()), 1.0),
                };
                match summary {
                    _ if ub <= best => 0, // cannot win: skip the probe
                    None => 0,
                    Some(_) if factor <= 0.0 => 0, // fully decayed
                    Some(summary) => {
                        let tracked = lease.and_then(|key| {
                            let (matched, len) = summary.tracked_prefix(key)?;
                            let tc = summary.tracked_chain_ref(key)?;
                            // Interned-node identity: the query extends the
                            // tracked chain iff walking back (len − tc.len)
                            // parents lands on tc's head node. O(delta).
                            let valid = len > 0 && chain.is_extension_of(tc);
                            if !valid {
                                return None;
                            }
                            Some(if matched < len {
                                // First miss inside the tracked prefix: a
                                // scan would stop exactly there.
                                matched
                            } else {
                                len + summary.matching_prefix(&chain.suffix(len))
                            })
                        });
                        let a = tracked.unwrap_or_else(|| {
                            let hashes = full.get_or_insert_with(|| chain.hashes());
                            summary.matching_prefix(hashes)
                        });
                        // Staleness decay: a sketch past the bound loses
                        // `decay_slope` of its value per further round.
                        let a = if factor < 1.0 {
                            (a as f64 * factor).floor() as usize
                        } else {
                            a
                        };
                        best = best.max((a + adapter_blocks) as f64 - penalty * load as f64);
                        a
                    }
                }
            };
            views.push(ReplicaView {
                load,
                affinity_blocks,
                adapter_blocks,
                free_blocks: if healthy {
                    self.replicas[i].num_free_blocks() as usize
                } else {
                    0
                },
                healthy,
                suspected: healthy && self.is_suspected(i),
                warming: healthy && self.warming[i],
            });
        }
        views
    }

    /// Ship a leased chain's blocks to `dest` instead of letting the next
    /// turn recompute them (DESIGN.md §18). The decision is a cost-model
    /// call on the destination's config: when the modeled transfer time
    /// beats prefilling the same blocks from token zero, the chain is
    /// installed into `dest`'s pool under the lease and the transfer time
    /// is charged on `dest`'s clock — the blocks are unusable before they
    /// arrive, so the cost lands in the next turn's TTFT exactly like the
    /// (more expensive) prefill it replaces would have. When the model
    /// says recompute wins — or the destination cannot take the blocks —
    /// NOTHING is mutated beyond the fallback counter, so the path is
    /// bit-identical to a fleet without migration.
    ///
    /// Returns the number of blocks installed (0 = recompute fallback).
    fn migrate_lease_to(&mut self, lease: u64, chain: &ChainRef, dest: usize) -> usize {
        if chain.is_empty() || self.health[dest] != ReplicaHealth::Up {
            return 0;
        }
        let cm = CostModel::new(&self.replicas[dest].cfg);
        if !cm.migration_wins(chain.len()) {
            self.router.stats.migration_recompute_fallbacks += 1;
            return 0;
        }
        // Exactly one replica ever pins a session's chain: drop any stale
        // copy elsewhere before installing (the draining source keeps its
        // unpinned committed blocks — same as a lease break — while a
        // down source already lost everything at `fail_storage`).
        for (i, r) in self.replicas.iter_mut().enumerate() {
            if i != dest {
                r.release_prefix_lease(lease);
            }
        }
        let now = self.clock();
        let r = &mut self.replicas[dest];
        if !r.has_work() && r.clock() < now {
            r.advance_clock_to(now);
        }
        let installed = r.install_migrated_lease(lease, chain);
        if installed == 0 {
            // No room at the destination: the prefix recomputes on demand.
            self.router.stats.migration_recompute_fallbacks += 1;
            return 0;
        }
        let arrival = r.clock() + cm.migration_time(installed);
        r.advance_clock_to(arrival);
        self.router.stats.migrations += 1;
        self.router.stats.migrated_blocks += installed as u64;
        installed
    }

    /// Ship a retiring replica's leased chains to survivors in ONE batch
    /// transfer (DESIGN.md §19): the per-destination clock charge pays
    /// `migration_setup` once for the whole group instead of once per
    /// session. Membership is cost-model-gated: if any chain justifies a
    /// transfer on its own (`migration_wins` — it would ship even solo,
    /// the acceptance bar), the batch forms and every chain whose
    /// marginal transfer beats its recompute
    /// (`batch_migration_member_wins`) rides along; without such an
    /// anchor nothing pays the setup and every chain recomputes. Returns
    /// the number of leases shipped.
    fn batch_migrate_leases(&mut self, victim: usize) -> usize {
        if !self.replicas[0].cfg.cache.prefix_migration || self.num_healthy() == 0 {
            return 0;
        }
        let cm = CostModel::new(&self.replicas[0].cfg);
        // Enumerate oldest-first (deterministic), decide membership
        // BEFORE any routing choice — a declined batch must leave the
        // router bit-identical to a fleet that never considered it.
        let mut anchor = false;
        let mut candidates: Vec<(u64, ChainRef)> = Vec::new();
        for key in self.replicas[victim].lease_keys() {
            let Some(chain) = self.replicas[victim].lease_chain(key) else {
                continue;
            };
            if chain.is_empty() {
                continue;
            }
            anchor |= cm.migration_wins(chain.len());
            candidates.push((key, chain));
        }
        let mut shipped = 0usize;
        // Blocks installed per destination: the one-time setup charge
        // lands once per destination clock, after all installs.
        let mut per_dest: FxHashMap<usize, usize> = FxHashMap::default();
        for (key, chain) in candidates {
            let wins = if anchor {
                cm.batch_migration_member_wins(chain.len())
            } else {
                cm.migration_wins(chain.len())
            };
            if !wins {
                self.router.stats.migration_recompute_fallbacks += 1;
                continue;
            }
            let views = self.views_for_chain(ModelTarget::Base, &chain, Some(key));
            let dest = self.router.choose(&views).replica;
            if self.health[dest] != ReplicaHealth::Up {
                self.router.stats.migration_recompute_fallbacks += 1;
                continue;
            }
            for i in 0..self.replicas.len() {
                if i != dest {
                    self.replicas[i].release_prefix_lease(key);
                }
            }
            let installed = self.replicas[dest].install_migrated_lease(key, &chain);
            if installed == 0 {
                // No room at the destination: recompute on demand.
                self.router.stats.migration_recompute_fallbacks += 1;
                continue;
            }
            self.router.stats.migrations += 1;
            self.router.stats.migrated_blocks += installed as u64;
            *per_dest.entry(dest).or_insert(0) += installed;
            shipped += 1;
        }
        let now = self.clock();
        let mut dests: Vec<(usize, usize)> = per_dest.into_iter().collect();
        dests.sort_unstable();
        for (dest, blocks) in dests {
            let r = &mut self.replicas[dest];
            if !r.has_work() && r.clock() < now {
                r.advance_clock_to(now);
            }
            let arrival = r.clock() + cm.batch_migration_time(blocks);
            r.advance_clock_to(arrival);
        }
        shipped
    }

    /// Activate a standby replica under a scale-up decision. It starts
    /// COLD: `warming` keeps it overflow-only (see `ReplicaView::warming`)
    /// until its gossiped summary holds `warmup_min_blocks` blocks.
    fn activate_standby(&mut self, i: usize) {
        debug_assert_eq!(self.health[i], ReplicaHealth::Standby);
        self.health[i] = ReplicaHealth::Up;
        self.silenced[i] = false;
        self.gossip[i] = None;
        self.warming[i] = self.fleet.warmup_min_blocks > 0;
        self.monitor.reset(i);
        self.router.stats.scale_ups += 1;
        self.autoscaler.note_scaled();
        // It genuinely sat idle until this instant.
        let now = self.clock();
        let r = &mut self.replicas[i];
        if r.clock() < now {
            r.advance_clock_to(now);
        }
    }

    /// A scale-down victim finished draining: batch-migrate its leased
    /// chains to survivors, release whatever the cost model declined,
    /// and park the replica in `Standby`. Its finished-but-undrained
    /// outputs survive (the completion ledger is serving-layer state).
    fn retire_drained(&mut self, victim: usize) {
        debug_assert_eq!(self.health[victim], ReplicaHealth::Draining);
        self.descaling = None;
        self.batch_migrate_leases(victim);
        for key in self.replicas[victim].lease_keys() {
            self.replicas[victim].release_prefix_lease(key);
        }
        self.gossip[victim] = None;
        self.warming[victim] = false;
        self.silenced[victim] = false;
        self.monitor.reset(victim);
        self.health[victim] = ReplicaHealth::Standby;
        self.router.stats.scale_downs += 1;
    }

    /// The self-driving control loop (DESIGN.md §19), run once at the end
    /// of every fleet step on the shared simulated clock: heartbeats →
    /// detection → gossip refresh → warm-up promotion → descale drain
    /// completion → autoscale decision. With the default [`FleetConfig`]
    /// and no silenced replica every branch below is a strict no-op, so a
    /// fleet that never opts in behaves bit-identically to one built
    /// before this loop existed.
    fn fleet_control(&mut self) {
        // 1. Heartbeats + failure detection. Detection latency is exact:
        //    one beat per step, Down on the `down_after_misses`-th miss.
        let beats: Vec<Beat> = (0..self.replicas.len())
            .map(|i| match self.health[i] {
                ReplicaHealth::Down | ReplicaHealth::Standby => Beat::Ignore,
                _ if self.silenced[i] => Beat::Missed,
                _ => Beat::Seen,
            })
            .collect();
        let obs = self.monitor.observe(&beats);
        self.router.stats.heartbeat_misses += obs.misses as u64;
        for t in obs.transitions {
            match t {
                Transition::Suspected { .. } => {
                    self.router.stats.suspected_transitions += 1;
                }
                Transition::Recovered { .. } => {}
                Transition::Down { replica } => {
                    self.router.stats.detected_failures += 1;
                    // The SAME pipeline an operator-declared
                    // `fail_replica` runs — evacuation, reversed requeue,
                    // lease orphaning — and exactly once (the monitor
                    // saturates, `fail_replica` re-pins it). If no
                    // healthy survivor exists the declaration is refused
                    // and the replica keeps its work: a lone partitioned
                    // replica has nowhere to fail over TO.
                    if let Ok(report) = Cluster::fail_replica(self, replica) {
                        self.pending_failovers.push(report);
                    }
                }
            }
        }
        // 2. Gossip refresh: every `gossip_period_steps` steps each
        //    participating replica publishes a snapshot of its routing
        //    summary stamped with the new round. A silenced replica stops
        //    publishing; once its last stamp falls `gossip_stale_rounds`
        //    behind, each further round counts one sketch decay.
        if self.fleet.gossip_period_steps > 0 {
            self.steps_since_gossip += 1;
            if self.steps_since_gossip >= self.fleet.gossip_period_steps {
                self.steps_since_gossip = 0;
                self.gossip_round += 1;
                for i in 0..self.replicas.len() {
                    match self.health[i] {
                        ReplicaHealth::Down | ReplicaHealth::Standby => {
                            self.gossip[i] = None;
                        }
                        _ if !self.silenced[i] => {
                            self.gossip[i] = Some((
                                self.replicas[i].routing_summary().clone(),
                                self.gossip_round,
                            ));
                        }
                        _ => {
                            if let Some((_, stamp)) = &self.gossip[i] {
                                let stale = self.gossip_round - stamp;
                                if stale > self.fleet.gossip_stale_rounds as u64 {
                                    self.router.stats.stale_sketch_decays += 1;
                                }
                            }
                        }
                    }
                }
            }
        }
        // 3. Warm-up promotion: a warming replica graduates once the
        //    summary the ROUTER sees for it (gossiped if gossip is on,
        //    live otherwise) holds enough blocks to score.
        for i in 0..self.replicas.len() {
            if !self.warming[i] {
                continue;
            }
            let committed = if self.fleet.gossip_period_steps > 0 {
                self.gossip[i].as_ref().map(|(s, _)| s.committed_blocks()).unwrap_or(0)
            } else {
                self.replicas[i].routing_summary().committed_blocks()
            };
            if committed as usize >= self.fleet.warmup_min_blocks {
                self.warming[i] = false;
            }
        }
        // 4. Descale drain completion: the victim retires only once its
        //    running AND waiting work is gone — an in-flight turn always
        //    finishes where it started.
        if let Some(victim) = self.descaling {
            if !self.replicas[victim].has_work() {
                self.retire_drained(victim);
            }
        }
        // 5. Autoscale decision.
        if !self.fleet.autoscale {
            return;
        }
        let mut active = 0usize;
        let mut waiting = 0usize;
        let mut kv_pressure = 0.0f64;
        let mut last_active = None;
        for i in 0..self.replicas.len() {
            if self.health[i] != ReplicaHealth::Up {
                continue;
            }
            active += 1;
            last_active = Some(i);
            let r = &self.replicas[i];
            waiting += r.num_waiting();
            let total = r.num_total_blocks() as f64;
            if total > 0.0 {
                kv_pressure =
                    kv_pressure.max(1.0 - r.num_free_blocks() as f64 / total);
            }
        }
        let standby =
            (0..self.replicas.len()).find(|&i| self.health[i] == ReplicaHealth::Standby);
        let signals = ScaleSignals {
            active_replicas: active,
            standby_available: standby.is_some(),
            waiting,
            kv_pressure,
            admission_watermark: self.replicas[0].cfg.scheduler.admission_watermark,
        };
        match self.autoscaler.observe(&signals) {
            ScaleDecision::Hold => {}
            ScaleDecision::Up => {
                if let Some(i) = standby {
                    self.activate_standby(i);
                }
            }
            ScaleDecision::Down => {
                // Highest-index active replica drains toward standby —
                // one descale in flight at a time, never the last
                // healthy replica (`drain_replica` enforces both floors).
                if self.descaling.is_none() {
                    if let Some(victim) = last_active {
                        if Cluster::drain_replica(self, victim).is_ok() {
                            self.descaling = Some(victim);
                            self.autoscaler.note_scaled();
                        }
                    }
                }
            }
        }
    }
}

/// The shared per-replica config summary (replicas are identical by
/// construction; a single engine is a fleet of one).
fn config_summary(cfg: &EngineConfig) -> ReplicaConfigSummary {
    ReplicaConfigSummary {
        model: cfg.model.name.clone(),
        block_size: cfg.cache.block_size,
        total_blocks: cfg.cache.num_blocks(),
        max_batch_tokens: cfg.scheduler.max_batch_tokens,
        max_num_seqs: cfg.scheduler.max_num_seqs,
        admission_watermark: cfg.scheduler.admission_watermark,
        base_aligned_hashing: cfg.cache.base_aligned_hashing,
        adapter_paging: cfg.cache.adapter_paging,
    }
}

/// One engine's stats row, shared by the fleet snapshot and the
/// single-engine `GET /cluster` document.
fn replica_stats<E: Executor>(
    i: usize,
    r: &Engine<E>,
    routed: u64,
    health: &'static str,
    health_detail: String,
) -> ReplicaStats {
    ReplicaStats {
        replica: i,
        health,
        health_detail,
        clock: r.clock(),
        running: r.num_running(),
        waiting: r.num_waiting(),
        finished: r.metrics.requests_finished,
        free_blocks: r.num_free_blocks(),
        total_blocks: r.num_total_blocks(),
        committed_blocks: r.routing_summary().committed_blocks(),
        hit_rate: r.kv_stats().hit_rate(),
        routed,
        resident_adapters: r.residency().resident_ids(),
        adapter_resident_blocks: r.residency().resident_blocks(),
        adapter_loads: r.residency().stats().loads,
        adapter_evictions: r.residency().stats().evictions,
        host_total_blocks: r.cfg.cache.host_adapter_blocks,
        adapter_host_blocks: r.residency().host_resident_blocks(),
        adapter_demotions: r.residency().stats().demotions,
        adapter_promotions: r.residency().stats().promotions,
        adapter_host_drops: r.residency().stats().host_drops,
        adapter_prefetches: r.residency().stats().prefetches,
    }
}

/// A one-replica `ClusterStats` for a single engine: `GET /cluster` on a
/// single-engine server returns this instead of 404 (API consistency —
/// dashboards built against the fleet shape work unchanged). Every
/// submission trivially "routed" to replica 0; policy reports "single".
pub fn single_engine_stats<E: Executor>(e: &Engine<E>) -> ClusterStats {
    let mut routing = RoutingMetrics::new(1);
    routing.routed[0] = e.metrics.requests_received;
    ClusterStats {
        policy: "single",
        config: config_summary(&e.cfg),
        replicas: vec![replica_stats(
            0,
            e,
            e.metrics.requests_received,
            "up",
            "up".to_string(),
        )],
        routing,
        fleet: FleetStats::single(),
        aggregate_hit_rate: e.kv_stats().hit_rate(),
        aggregate_adapter_hit_rate: e.residency().stats().hit_rate(),
    }
}

impl<E: Executor> EngineDriver for Cluster<E> {
    fn submit_salted(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
    ) -> anyhow::Result<RequestId> {
        anyhow::ensure!(
            self.num_healthy() > 0,
            "no healthy replicas: the whole fleet is down or draining"
        );
        let (views, chain) = self.views_for(target, &prompt, cache_salt);
        let placement = self.router.choose(&views);
        let now = self.clock();
        let r = &mut self.replicas[placement.replica];
        // An idle replica's clock lags only because nothing advanced it;
        // the request really arrives at fleet time, so sync forward. Busy
        // replicas keep their own timeline (jumping it would stretch
        // in-flight work). Under the event drive this approximation is
        // tight — arrivals are gated on the fleet clock every step, so the
        // sync target is at most one scheduling quantum past the nominal
        // arrival. (Advancing before a rejected submission is harmless:
        // the clock only moves forward and no request is created.)
        if !r.has_work() && r.clock() < now {
            r.advance_clock_to(now);
        }
        let id = r.submit_prehashed(target, prompt, params, priority, cache_salt, chain)?;
        // Count the placement only now: rejected submissions must not
        // skew the routing stats.
        self.router.record(placement);
        Ok(id)
    }

    /// Session stickiness: a conversation turn lands on the replica that
    /// ran its previous turn — `peer`'s replica is a construction-time
    /// fact (ids are partitioned `replica = id % n`, overridden by the
    /// failover re-home map), so no summary scoring is needed and the
    /// warm prefix is guaranteed co-located. First turns (no peer) fall
    /// through to the routing policy; so does a turn whose replica is
    /// down or draining — the conversation re-sticks wherever its chain
    /// scores best (PrefixAffinity finds any surviving copy; cold via the
    /// least-loaded fallback if nothing survives), counted as a re-stick.
    fn submit_sticky(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
        peer: Option<RequestId>,
    ) -> anyhow::Result<RequestId> {
        let Some(peer) = peer else {
            return self.submit_salted(target, prompt, params, priority, cache_salt);
        };
        let ri = self.replica_of(peer);
        if self.health[ri] != ReplicaHealth::Up {
            self.router.stats.resticks += 1;
            return self.submit_salted(target, prompt, params, priority, cache_salt);
        }
        let now = self.clock();
        let r = &mut self.replicas[ri];
        // Same idle-clock sync as routed submission: the turn arrives at
        // fleet time even if its replica sat idle between turns.
        if !r.has_work() && r.clock() < now {
            r.advance_clock_to(now);
        }
        let id = r.submit_salted(target, prompt, params, priority, cache_salt)?;
        self.router.record_sticky(ri);
        Ok(id)
    }

    /// The hot path for conversation turns at scale: the session layer
    /// already extended its cached chain by the delta turn, so neither
    /// the sticky fast path (no routing scan at all) nor the re-stick
    /// fallback (scored via [`Cluster::views_for_chain`] with the lease
    /// hint) rehashes the conversation history — per-turn placement work
    /// is O(delta + replicas), independent of how long the session is.
    fn submit_sticky_prehashed(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
        peer: Option<RequestId>,
        lease: Option<u64>,
        chain: ChainRef,
    ) -> anyhow::Result<RequestId> {
        let sticky = peer.map(|p| self.replica_of(p));
        match sticky {
            Some(ri) if self.health[ri] == ReplicaHealth::Up => {
                let now = self.clock();
                let r = &mut self.replicas[ri];
                if !r.has_work() && r.clock() < now {
                    r.advance_clock_to(now);
                }
                let id =
                    r.submit_prehashed(target, prompt, params, priority, cache_salt, chain)?;
                self.router.record_sticky(ri);
                Ok(id)
            }
            unstuck => {
                anyhow::ensure!(
                    self.num_healthy() > 0,
                    "no healthy replicas: the whole fleet is down or draining"
                );
                if unstuck.is_some() {
                    // The conversation's replica is down or draining:
                    // re-stick through the routing policy.
                    self.router.stats.resticks += 1;
                }
                // Chain-blind policies never look at affinity; don't pay
                // for probes they'd ignore (mirrors `views_for`).
                let empty = ChainRef::empty();
                let score_chain =
                    if self.router.needs_chain() { &chain } else { &empty };
                let views = self.views_for_chain(target, score_chain, lease);
                let placement = self.router.choose(&views);
                // Drain migration (DESIGN.md §18): if the conversation's
                // old replica still pins its chain — only a DRAINING
                // source can; a down one released everything at
                // `fail_storage` — and this turn extends that chain but
                // lands elsewhere, ship the pinned blocks to the new home
                // instead of recomputing them (cost model permitting).
                if self.replicas[0].cfg.cache.prefix_migration {
                    if let Some(key) = lease {
                        let src = (0..self.replicas.len()).find_map(|i| {
                            self.replicas[i].lease_chain(key).map(|c| (i, c))
                        });
                        if let Some((src, leased)) = src {
                            if src != placement.replica
                                && !leased.is_empty()
                                && chain.is_extension_of(&leased)
                            {
                                self.migrate_lease_to(key, &leased, placement.replica);
                            }
                        }
                    }
                }
                let now = self.clock();
                let r = &mut self.replicas[placement.replica];
                if !r.has_work() && r.clock() < now {
                    r.advance_clock_to(now);
                }
                let id =
                    r.submit_prehashed(target, prompt, params, priority, cache_salt, chain)?;
                self.router.record(placement);
                Ok(id)
            }
        }
    }

    fn watch(&mut self, id: RequestId) {
        let ri = self.replica_of(id);
        self.replicas[ri].watch(id);
    }

    fn unwatch(&mut self, id: RequestId) {
        let ri = self.replica_of(id);
        self.replicas[ri].unwatch(id);
    }

    fn take_events(&mut self) -> Vec<TurnEvent> {
        let mut out = Vec::new();
        for r in &mut self.replicas {
            out.append(&mut r.take_events());
        }
        out
    }

    /// The lease lives where the blocks live: on `peer`'s replica (the
    /// turn that just committed the chain there, located through the
    /// failover re-home map). Any stale copy of the lease on other
    /// replicas — a conversation migrates when its replica fails or
    /// drains — is released first, so exactly one replica ever pins a
    /// session's chain. No peer = no turn has run = nothing to pin; a
    /// down peer replica = the blocks are gone = nothing to pin either.
    fn acquire_lease(
        &mut self,
        lease: u64,
        tokens: &[u32],
        cache_salt: u64,
        peer: Option<RequestId>,
    ) -> usize {
        let Some(peer) = peer else { return 0 };
        let ri = self.replica_of(peer);
        for (i, r) in self.replicas.iter_mut().enumerate() {
            if i != ri {
                r.release_prefix_lease(lease);
            }
        }
        if matches!(self.health[ri], ReplicaHealth::Down | ReplicaHealth::Standby) {
            return 0;
        }
        self.replicas[ri].lease_prefix(lease, tokens, cache_salt)
    }

    /// Prehashed form of [`EngineDriver::acquire_lease`]: the session
    /// layer's cached chain goes straight to the replica's lease table,
    /// which extends an existing lease in O(delta) — no per-turn rehash
    /// of the conversation history, no full re-pin.
    fn acquire_lease_prehashed(
        &mut self,
        lease: u64,
        chain: &ChainRef,
        peer: Option<RequestId>,
    ) -> usize {
        let Some(peer) = peer else { return 0 };
        let ri = self.replica_of(peer);
        for (i, r) in self.replicas.iter_mut().enumerate() {
            if i != ri {
                r.release_prefix_lease(lease);
            }
        }
        if matches!(self.health[ri], ReplicaHealth::Down | ReplicaHealth::Standby) {
            return 0;
        }
        self.replicas[ri].lease_prefix_prehashed(lease, chain)
    }

    fn release_lease(&mut self, lease: u64) {
        for r in &mut self.replicas {
            r.release_prefix_lease(lease);
        }
    }

    /// One fleet step: every live replica with work advances by one batch
    /// (they are parallel machines). Down replicas never step — their
    /// work was evacuated at failure, and a dead machine computes
    /// nothing; standby replicas are not running. The self-driving
    /// control loop (heartbeats, gossip, autoscaling — DESIGN.md §19)
    /// runs after the compute, once per step. False only when no replica
    /// progressed.
    fn step(&mut self) -> bool {
        let mut progressed = false;
        for (i, r) in self.replicas.iter_mut().enumerate() {
            if matches!(self.health[i], ReplicaHealth::Down | ReplicaHealth::Standby) {
                continue;
            }
            if r.has_work() {
                progressed |= r.step();
            }
        }
        self.fleet_control();
        progressed
    }

    fn clock(&self) -> f64 {
        self.replicas.iter().map(|r| r.clock()).fold(0.0, f64::max)
    }

    fn advance_clock_to(&mut self, t: f64) {
        for r in &mut self.replicas {
            if r.clock() < t {
                r.advance_clock_to(t);
            }
        }
    }

    fn has_work(&self) -> bool {
        self.replicas.iter().any(|r| r.has_work())
    }

    fn num_waiting(&self) -> usize {
        self.replicas.iter().map(|r| r.num_waiting()).sum()
    }

    fn num_running(&self) -> usize {
        self.replicas.iter().map(|r| r.num_running()).sum()
    }

    fn take_finished(&mut self) -> Vec<RequestOutput> {
        let mut out = Vec::new();
        for r in &mut self.replicas {
            out.append(&mut r.take_finished());
        }
        out
    }

    fn finished_pending(&self) -> usize {
        self.replicas.iter().map(|r| r.finished_pending()).sum()
    }

    fn take_finished_where<F: FnMut(&RequestOutput) -> bool>(
        &mut self,
        mut pred: F,
    ) -> Vec<RequestOutput> {
        let mut out = Vec::new();
        for r in &mut self.replicas {
            out.extend(r.take_finished_where(&mut pred));
        }
        out
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    fn config(&self) -> &EngineConfig {
        &self.replicas[0].cfg
    }

    fn registry(&self) -> &AdapterRegistry {
        &self.replicas[0].registry
    }

    /// Fleet exposition: aggregated single-engine families (counters and
    /// histograms summed, clock = makespan) + the fleet-level per-stage
    /// series + routing counters + per-replica labeled families. Every
    /// family appears exactly once, and — scrape path — nothing O(total
    /// requests served) is copied: only scalars and fixed-bucket
    /// histograms aggregate, and the stage series render by reference.
    fn render_prometheus(&self) -> String {
        let mut agg = Metrics::new();
        agg.absorb_scalars(&self.metrics);
        for r in &self.replicas {
            agg.absorb_scalars(&r.metrics);
        }
        let mut s = agg.render_prometheus();
        // The coordinator's stage series and the session layer's per-turn
        // series are recorded through metrics_mut(), i.e. on the fleet
        // registry — replicas never carry any (and the aggregated scalars
        // above rendered an empty turn series, so each family appears
        // exactly once).
        s.push_str(&Metrics::render_turn_series(&self.metrics.turn));
        s.push_str(&Metrics::render_stage_series(&self.metrics.stage));
        s.push_str(&self.router.stats.render_prometheus());
        let per: Vec<&Metrics> = self.replicas.iter().map(|r| &r.metrics).collect();
        s.push_str(&Metrics::render_replica_families(&per));
        s
    }

    fn cluster_stats(&self) -> Option<ClusterStats> {
        Some(self.stats())
    }

    fn fail_replica(&mut self, i: usize) -> anyhow::Result<FailoverReport> {
        Cluster::fail_replica(self, i)
    }

    fn drain_replica(&mut self, i: usize) -> anyhow::Result<()> {
        Cluster::drain_replica(self, i)
    }

    fn restore_replica(&mut self, i: usize) -> anyhow::Result<()> {
        Cluster::restore_replica(self, i)
    }

    fn silence_replica(&mut self, i: usize) -> anyhow::Result<()> {
        Cluster::silence_replica(self, i)
    }

    fn take_failover_reports(&mut self) -> Vec<FailoverReport> {
        Cluster::take_failover_reports(self)
    }

    fn cluster_health(&self) -> Option<Json> {
        Some(self.health_doc())
    }

    fn note_resticks(&mut self, n: u64) {
        self.router.stats.resticks += n;
    }

    /// Re-home a session's pinned chain after failover (DESIGN.md §18):
    /// the destination is the peer's replica when that replica is up (the
    /// session's requeued turn already landed there, so the blocks must
    /// follow it), else the routing policy's pick for the chain — chosen
    /// but NOT recorded, because a migration is not a request placement.
    /// Gated on `cache.prefix_migration`; off (the default), every call
    /// returns 0 and the fleet recomputes exactly as before the flag
    /// existed.
    fn migrate_lease(&mut self, lease: u64, chain: &ChainRef, peer: Option<RequestId>) -> usize {
        if !self.replicas[0].cfg.cache.prefix_migration || chain.is_empty() {
            return 0;
        }
        // Decide BEFORE picking a destination: `Router::choose` may
        // advance policy state (the round-robin cursor), and a declined
        // migration must leave the fleet bit-identical to one that never
        // considered migrating. Replicas are identical by construction,
        // so replica 0's cost model speaks for any destination.
        if !CostModel::new(&self.replicas[0].cfg).migration_wins(chain.len()) {
            self.router.stats.migration_recompute_fallbacks += 1;
            return 0;
        }
        let dest = match peer.map(|p| self.replica_of(p)) {
            Some(ri) if self.health[ri] == ReplicaHealth::Up => ri,
            _ => {
                if self.num_healthy() == 0 {
                    return 0;
                }
                let views = self.views_for_chain(ModelTarget::Base, chain, Some(lease));
                self.router.choose(&views).replica
            }
        };
        self.migrate_lease_to(lease, chain, dest)
    }

    fn note_session_forks(&mut self, n: u64) {
        self.router.stats.session_forks += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::AdapterId;
    use crate::config::presets;
    use crate::pipeline::workload;
    use crate::simulator::SimExecutor;

    fn cluster(n: usize, policy: RoutePolicy) -> Cluster<SimExecutor> {
        Cluster::from_factory(n, policy, |_| {
            let cfg = presets::granite_8b();
            let reg = workload::build_registry(2, cfg.model.vocab_size, true);
            let exec = SimExecutor::new(&cfg);
            Engine::with_registry(cfg, reg, exec)
        })
        .unwrap()
    }

    /// Two-replica affinity fleet with prefix migration switchable — the
    /// migration tests run both arms of the flag on otherwise identical
    /// fleets and compare.
    fn session_cluster(migrate: bool) -> Cluster<SimExecutor> {
        Cluster::from_factory(2, RoutePolicy::PrefixAffinity, |_| {
            let mut cfg = presets::granite_8b();
            cfg.cache.prefix_migration = migrate;
            let reg = workload::build_registry(2, cfg.model.vocab_size, true);
            let exec = SimExecutor::new(&cfg);
            Engine::with_registry(cfg, reg, exec)
        })
        .unwrap()
    }

    #[test]
    fn ids_are_fleet_unique_and_interleaved() {
        let mut c = cluster(3, RoutePolicy::RoundRobin);
        let mut ids = Vec::new();
        for i in 0..6 {
            ids.push(
                c.submit(
                    ModelTarget::Base,
                    vec![1 + i; 32],
                    SamplingParams { max_new_tokens: 2, ..Default::default() },
                )
                .unwrap(),
            );
        }
        let mut sorted = ids.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 6, "duplicate ids across replicas: {ids:?}");
        // RR: request k lands on replica k%3, which issues k%3 + 3*floor(k/3).
        assert_eq!(ids, (0..6).map(RequestId).collect::<Vec<_>>());
        c.run_until_idle();
        assert_eq!(c.take_finished().len(), 6);
        assert!(!c.has_work());
    }

    #[test]
    fn single_replica_cluster_matches_plain_engine() {
        let run = |clustered: bool| {
            let prompt: Vec<u32> = (0..256).collect();
            let p = SamplingParams { max_new_tokens: 16, ..Default::default() };
            if clustered {
                let mut c = cluster(1, RoutePolicy::RoundRobin);
                c.submit(ModelTarget::Base, prompt.clone(), p).unwrap();
                c.run_until_idle();
                (c.clock(), c.take_finished().len())
            } else {
                let cfg = presets::granite_8b();
                let reg = workload::build_registry(2, cfg.model.vocab_size, true);
                let mut e = Engine::with_registry(cfg.clone(), reg, SimExecutor::new(&cfg));
                e.submit(ModelTarget::Base, prompt.clone(), p).unwrap();
                e.run_until_idle();
                (e.clock(), e.take_finished().len())
            }
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn affinity_routes_follow_up_to_warm_replica() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let prompt: Vec<u32> = (0..256).collect();
        let p = SamplingParams { max_new_tokens: 16, ..Default::default() };
        // Cold conversation: least-loaded fallback → replica 0.
        c.submit(ModelTarget::Base, prompt.clone(), p).unwrap();
        c.run_until_idle();
        let first = c.take_finished().pop().unwrap();
        assert_eq!(c.router().stats.affinity_fallbacks, 1);
        // Follow-up extends the conversation: must land on replica 0 and
        // hit its cached prefix, not re-prefill on replica 1.
        let mut follow = prompt.clone();
        follow.extend(&first.output_tokens);
        follow.push(7);
        c.submit(ModelTarget::Base, follow, p).unwrap();
        c.run_until_idle();
        let second = c.take_finished().pop().unwrap();
        assert_eq!(c.router().stats.affinity_hits, 1);
        assert_eq!(c.router().stats.routed, vec![2, 0]);
        assert_eq!(second.num_cached_tokens, 256, "warm-replica prefix hit");
        // And the adapter direction: an aLoRA eval over the conversation
        // shares the base prefix, so it must land warm too.
        let mut ev = prompt.clone();
        ev.extend(&first.output_tokens);
        ev.extend(workload::invocation_for(c.config().model.vocab_size, 0));
        c.submit(ModelTarget::Adapter(AdapterId(0)), ev, p).unwrap();
        c.run_until_idle();
        let eval = c.take_finished().pop().unwrap();
        assert!(eval.num_cached_tokens >= 256, "cross-model affinity hit");
        assert_eq!(c.router().stats.routed, vec![3, 0]);
    }

    #[test]
    fn cluster_stats_and_prometheus_render() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        c.submit(
            ModelTarget::Base,
            (0..64).collect(),
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        )
        .unwrap();
        c.run_until_idle();
        let st = c.stats();
        assert_eq!(st.policy, "prefix-affinity");
        assert_eq!(st.replicas.len(), 2);
        assert_eq!(st.routing.total_routed(), 1);
        assert!(st.replicas.iter().any(|r| r.committed_blocks > 0));
        // Config summary rides along so dashboards don't need out-of-band
        // config (satellite: per-replica block budget + paging flag).
        assert_eq!(st.config.model, "granite-8b");
        assert_eq!(st.config.total_blocks, 21_944);
        assert!(!st.config.adapter_paging);
        assert!(st.replicas.iter().all(|r| r.resident_adapters.is_empty()));
        let j = st.to_json().to_string();
        assert!(j.contains("\"policy\":\"prefix-affinity\""), "{j}");
        assert!(j.contains("\"config\":{"), "{j}");
        assert!(j.contains("\"total_blocks\":21944"), "{j}");
        assert!(j.contains("\"adapter_paging\":false"), "{j}");
        assert!(j.contains("\"resident_adapters\":[]"), "{j}");
        let prom = c.render_prometheus();
        assert!(prom.contains("alora_serve_requests_finished_total 1"), "{prom}");
        assert!(prom.contains("alora_serve_router_requests_routed_total{replica=\"0\"}"));
        assert!(prom.contains("alora_serve_replica_clock_seconds{replica=\"1\"}"));
    }

    #[test]
    fn rejected_submission_leaves_routing_stats_untouched() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let max = c.config().scheduler.max_seq_len as usize;
        let err = c.submit(
            ModelTarget::Base,
            vec![1; max + 1],
            SamplingParams { max_new_tokens: 1, ..Default::default() },
        );
        assert!(err.is_err());
        assert_eq!(c.router().stats.total_routed(), 0);
        assert_eq!(c.router().stats.affinity_fallbacks, 0);
    }

    #[test]
    fn adapter_affinity_converges_replicas_on_hot_subsets() {
        // Paged fleet: 128-block budget per replica, 3 aLoRAs × 32 weight
        // blocks. Round 1 spreads cold adapters by load; from round 2 on,
        // each adapter's requests go home to the replica holding its
        // weights — replicas converge on disjoint hot subsets instead of
        // all replicas paging all adapters (S-LoRA-style placement).
        let mut c = Cluster::from_factory(2, RoutePolicy::AdapterAffinity, |_| {
            let mut cfg = presets::granite_8b();
            cfg.scheduler.max_seq_len = 2048;
            cfg.cache.max_kv_tokens = 2048; // 128 blocks
            cfg.cache.adapter_paging = true;
            let reg = workload::build_registry(3, cfg.model.vocab_size, true);
            let exec = SimExecutor::new(&cfg);
            Engine::with_registry(cfg, reg, exec)
        })
        .unwrap();
        let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
        let mut rng = crate::util::rng::Rng::new(3);
        let vocab = c.config().model.vocab_size;
        for _round in 0..3 {
            for a in 0..3u32 {
                let prompt = workload::prompt(&mut rng, 256, vocab);
                c.submit(ModelTarget::Adapter(AdapterId(a)), prompt, p).unwrap();
            }
            c.run_until_idle();
        }
        let st = c.stats();
        assert_eq!(st.config.total_blocks, 128);
        assert!(st.config.adapter_paging);
        // Every adapter found a home; the fleet holds each exactly once.
        let mut all: Vec<u32> = st
            .replicas
            .iter()
            .flat_map(|r| r.resident_adapters.clone())
            .collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2], "disjoint hot subsets: {st:?}");
        // Rounds 2 and 3 were all residency hits: 6 of 9 admissions warm,
        // and no adapter was ever evicted (stable placement, no thrash).
        assert!((c.aggregate_adapter_hit_rate() - 6.0 / 9.0).abs() < 1e-12);
        let loads: u64 = st.replicas.iter().map(|r| r.adapter_loads).sum();
        let evictions: u64 = st.replicas.iter().map(|r| r.adapter_evictions).sum();
        assert_eq!(loads, 3, "one load per adapter, ever");
        assert_eq!(evictions, 0);
        assert_eq!(c.router().stats.affinity_hits, 6);
        let j = st.to_json().to_string();
        assert!(j.contains("\"aggregate_adapter_hit_rate\""), "{j}");
    }

    #[test]
    fn session_turns_stick_to_their_replica_and_stream_events() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let mut mgr = crate::session::SessionManager::new();
        let sid = mgr.create(0);
        let t1 = mgr
            .run_turn(&mut c, sid, ModelTarget::Base, (0..256).collect(), 16, true)
            .unwrap();
        assert_eq!(t1.cached_tokens, 0, "cold first turn");
        assert_eq!(c.router().stats.affinity_fallbacks, 1);
        // Follow-up turn: pinned to the conversation's replica without
        // scoring, and warm by construction. Watched: events flow back
        // through the fleet-uniform surface.
        let (_tid, rid) = mgr
            .begin_turn(&mut c, sid, ModelTarget::Base, (900..964).collect(), 16, true)
            .unwrap();
        c.watch(rid);
        let out = loop {
            if let Some(o) = c.take_finished_where(|o| o.id == rid).pop() {
                break o;
            }
            assert!(c.step(), "cluster stalled");
        };
        let evs = c.take_events();
        assert!(evs.iter().all(|e| e.id() == rid));
        assert!(matches!(
            evs.last(),
            Some(crate::request::TurnEvent::Finished { .. })
        ));
        let t2 = mgr.complete_turn(&mut c, sid, &out).unwrap();
        assert_eq!(c.router().stats.sticky_routed, 1);
        assert_eq!(c.router().stats.routed, vec![2, 0]);
        assert!(t2.cached_tokens >= 256, "sticky turn warm: {}", t2.cached_tokens);
        // The lease pins the chain on the conversation's replica only.
        assert!(c.replica(0).leased_blocks() > 0);
        assert_eq!(c.replica(1).leased_blocks(), 0);
        let j = c.stats().to_json().to_string();
        assert!(j.contains("\"sticky_routed\":1"), "{j}");
        // Deleting the session releases the lease fleet-wide.
        mgr.delete(&mut c, sid).unwrap();
        assert_eq!(c.replica(0).leased_blocks(), 0);
        c.replica(0).check_invariants().unwrap();
    }

    #[test]
    fn fail_replica_requeues_in_flight_and_waiting_with_ids_preserved() {
        let mut c = cluster(2, RoutePolicy::RoundRobin);
        let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
        let mut ids = Vec::new();
        for i in 0..6u32 {
            ids.push(
                c.submit(ModelTarget::Base, vec![10 + i; 64], p).unwrap(),
            );
        }
        // Get replica 1's share in flight (prefilling/decoding), then
        // kill it: ids 1, 3, 5 live there (RR interleave).
        for _ in 0..2 {
            c.step();
        }
        let report = c.fail_replica(1).unwrap();
        assert_eq!(c.health(1), ReplicaHealth::Down);
        assert_eq!(report.requeued, 3);
        assert!(report.rejected.is_empty());
        assert_eq!(c.router().stats.requeued_requests, 3);
        assert_eq!(c.router().stats.replica_failures, 1);
        assert_eq!(c.replica(1).num_running() + c.replica(1).num_waiting(), 0);
        // Every caller still gets its output, under its original id.
        c.run_until_idle();
        let outs = c.take_finished();
        let mut got: Vec<RequestId> = outs.iter().map(|o| o.id).collect();
        got.sort();
        assert_eq!(got, ids, "zero lost requests, fleet-unique ids preserved");
        // The victim is cold and empty; the survivor holds all the state.
        assert_eq!(c.replica(1).routing_summary().committed_blocks(), 0);
        assert_eq!(c.replica(1).num_free_blocks(), c.replica(1).num_total_blocks());
        c.replica(0).check_invariants().unwrap();
        c.replica(1).check_invariants().unwrap();
        // Fleet-wide received counter is not double-counted by the requeue.
        assert_eq!(c.aggregate_metrics().requests_received, 6);
        assert_eq!(c.aggregate_metrics().requests_finished, 6);
    }

    #[test]
    fn health_transition_guards() {
        let mut c = cluster(2, RoutePolicy::RoundRobin);
        // Restore an up replica: refused.
        assert!(c.restore_replica(0).unwrap_err().to_string().contains("already up"));
        // Unknown replica index.
        assert!(c.fail_replica(9).unwrap_err().to_string().contains("no replica 9"));
        c.fail_replica(1).unwrap();
        // Double fail refused; failing the last healthy refused.
        assert!(c.fail_replica(1).unwrap_err().to_string().contains("already down"));
        assert!(c
            .fail_replica(0)
            .unwrap_err()
            .to_string()
            .contains("no healthy survivor"));
        assert!(c.drain_replica(0).unwrap_err().to_string().contains("last healthy"));
        // Draining a down replica refused; restore brings it back up.
        assert!(c.drain_replica(1).is_err());
        c.restore_replica(1).unwrap();
        assert_eq!(c.health(1), ReplicaHealth::Up);
        // Now draining 0 works (1 is healthy again), and submissions
        // avoid it.
        c.drain_replica(0).unwrap();
        let p = SamplingParams { max_new_tokens: 2, ..Default::default() };
        for i in 0..3 {
            c.submit(ModelTarget::Base, vec![i + 1; 32], p).unwrap();
        }
        assert_eq!(c.router().stats.routed, vec![0, 3], "drained replica excluded");
        c.run_until_idle();
    }

    #[test]
    fn drain_finishes_in_flight_work_before_exclusion() {
        let mut c = cluster(2, RoutePolicy::RoundRobin);
        let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
        let a = c.submit(ModelTarget::Base, vec![1; 64], p).unwrap(); // replica 0
        let b = c.submit(ModelTarget::Base, vec![2; 64], p).unwrap(); // replica 1
        c.step();
        c.drain_replica(1).unwrap();
        assert_eq!(c.health(1), ReplicaHealth::Draining);
        // New traffic all lands on replica 0...
        for i in 0..4 {
            c.submit(ModelTarget::Base, vec![10 + i; 32], p).unwrap();
        }
        assert_eq!(c.router().stats.routed[1], 1, "no new placements while draining");
        // ...while the draining replica still finishes its own request.
        c.run_until_idle();
        let outs = c.take_finished();
        assert!(outs.iter().any(|o| o.id == a));
        assert!(outs.iter().any(|o| o.id == b), "draining replica finished its work");
        assert_eq!(c.replica(1).metrics.requests_finished, 1);
        c.replica(1).check_invariants().unwrap();
    }

    #[test]
    fn failed_replica_session_resticks_and_rebuilds_lease() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let mut mgr = crate::session::SessionManager::new();
        let sid = mgr.create(0);
        let t1 = mgr
            .run_turn(&mut c, sid, ModelTarget::Base, (0..256).collect(), 16, true)
            .unwrap();
        assert_eq!(t1.cached_tokens, 0);
        let home = (mgr.get(sid).unwrap().last_request.unwrap().0 % 2) as usize;
        assert!(c.replica(home).leased_blocks() > 0);
        // Kill the conversation's replica between turns: the lease
        // orphans, the repair clears stickiness, and the next turn
        // re-sticks cold on the survivor — recomputed tokens, no error.
        let report = c.fail_replica(home).unwrap();
        assert_eq!(report.requeued, 0, "nothing was in flight");
        assert_eq!(report.orphaned_leases, vec![sid.0]);
        let (leases, unstuck, aborted) = mgr.repair_after_failover(&mut c, &report);
        assert_eq!((leases, unstuck, aborted), (1, 1, 0));
        assert_eq!(mgr.get(sid).unwrap().leased_blocks, 0);
        assert!(mgr.get(sid).unwrap().last_request.is_none());
        assert_eq!(c.router().stats.resticks, 1);
        let t2 = mgr
            .run_turn(&mut c, sid, ModelTarget::Base, (900..932).collect(), 16, true)
            .unwrap();
        assert_eq!(t2.cached_tokens, 0, "chain transparently recomputed");
        let survivor = 1 - home;
        assert!(c.replica(survivor).leased_blocks() > 0, "lease rebuilt");
        assert_eq!(c.router().stats.orphaned_leases, 1);
        // Turn 3 is warm again on the survivor, sticky this time.
        let t3 = mgr
            .run_turn(&mut c, sid, ModelTarget::Base, (950..966).collect(), 16, true)
            .unwrap();
        assert!(t3.cached_tokens > 256, "re-warmed: {}", t3.cached_tokens);
        assert_eq!(c.router().stats.sticky_routed, 1, "only the re-warmed turn stuck");
        // The fleet document reports the failover activity alongside the
        // per-replica health — not just Prometheus.
        let j = c.stats().to_json().to_string();
        assert!(j.contains("\"replica_failures\":1"), "{j}");
        assert!(j.contains("\"orphaned_leases\":1"), "{j}");
        assert!(j.contains("\"resticks\":1"), "{j}");
        assert!(j.contains("\"health\":\"down\""), "{j}");
        assert!(j.contains("\"health\":\"up\""), "{j}");
        mgr.delete(&mut c, sid).unwrap();
        c.replica(survivor).check_invariants().unwrap();
    }

    #[test]
    fn sticky_turn_to_draining_replica_resticks_via_policy() {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let mut mgr = crate::session::SessionManager::new();
        let sid = mgr.create(0);
        mgr.run_turn(&mut c, sid, ModelTarget::Base, (0..256).collect(), 16, true)
            .unwrap();
        let home = (mgr.get(sid).unwrap().last_request.unwrap().0 % 2) as usize;
        c.drain_replica(home).unwrap();
        // The sticky peer is draining: the turn re-sticks via the policy.
        // PrefixAffinity scores only healthy replicas, and the chain lives
        // on the draining one — so the turn lands cold on the other.
        let t2 = mgr
            .run_turn(&mut c, sid, ModelTarget::Base, (900..932).collect(), 16, true)
            .unwrap();
        assert_eq!(c.router().stats.resticks, 1);
        assert_eq!(c.router().stats.sticky_routed, 0);
        assert_eq!(t2.cached_tokens, 0, "drained replica's cache unreachable");
        // The lease moved: exactly one replica pins the chain, and it is
        // the healthy one.
        let healthy = 1 - home;
        assert!(c.replica(healthy).leased_blocks() > 0);
        assert_eq!(c.replica(home).leased_blocks(), 0, "stale lease released");
        mgr.delete(&mut c, sid).unwrap();
    }

    #[test]
    fn turn_metrics_counted_exactly_once_in_aggregate_and_scrape() {
        // ISSUE-5 satellite: in cluster mode complete_turn records the
        // turn series on the fleet registry while aggregate_metrics()
        // absorbs fleet + every replica — samples must appear exactly
        // once, and repeated aggregation must be idempotent.
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let mut mgr = crate::session::SessionManager::new();
        let sid = mgr.create(0);
        for t in 0..3u32 {
            mgr.run_turn(
                &mut c,
                sid,
                ModelTarget::Base,
                (t * 100..t * 100 + 64).collect(),
                8,
                true,
            )
            .unwrap();
        }
        // The series lives on the fleet registry only — replicas carry none.
        assert_eq!(c.metrics.turn.count(), 3);
        assert!(c.replicas.iter().all(|r| r.metrics.turn.count() == 0));
        let agg = c.aggregate_metrics();
        assert_eq!(agg.turn.count(), 3, "each turn sampled exactly once");
        assert_eq!(agg.requests_finished, 3);
        // Idempotence: aggregating again yields the same counts (absorb
        // never mutates the sources).
        let agg2 = c.aggregate_metrics();
        assert_eq!(agg2.turn.count(), 3);
        assert_eq!(agg2.requests_finished, agg.requests_finished);
        assert_eq!(agg2.all.count(), agg.all.count());
        // The scrape renders the turn family exactly once, with the fleet
        // count — not doubled by the aggregated (empty) registry's.
        let prom = c.render_prometheus();
        assert_eq!(prom.matches("# HELP alora_serve_turns_total").count(), 1);
        assert!(prom.contains("alora_serve_turns_total 3"), "{prom}");
        let prom2 = c.render_prometheus();
        assert_eq!(prom, prom2, "scrape is idempotent");
        mgr.delete(&mut c, sid).unwrap();
    }

    #[test]
    fn least_loaded_balances_cold_traffic() {
        let mut c = cluster(2, RoutePolicy::LeastLoaded);
        for i in 0..8 {
            c.submit(
                ModelTarget::Base,
                vec![100 + i; 64],
                SamplingParams { max_new_tokens: 4, ..Default::default() },
            )
            .unwrap();
        }
        let routed = c.router().stats.routed.clone();
        assert_eq!(routed, vec![4, 4], "cold uniform load must split evenly");
        c.run_until_idle();
    }

    #[test]
    fn relocation_refresh_is_constant_time_and_evicts_in_order() {
        // ISSUE-8 satellite: re-relocating an id must not scan the order
        // queue. The refreshed entry re-enters at the back under a fresh
        // epoch; the stale front entry drains as a tombstone without
        // forgetting the live re-home.
        let mut c = cluster(2, RoutePolicy::RoundRobin);
        let x = RequestId(9); // id % 2 == 1 once forgotten
        c.note_relocation(x, 0);
        c.note_relocation(x, 0); // refresh: front entry is now a tombstone
        assert_eq!(c.replica_of(x), 0);
        // Fill the window. The tombstone is evicted first (it dilutes
        // capacity by one slot) but x's live entry — re-stamped at the
        // back — must survive the whole sweep.
        for i in 0..(MAX_RELOCATIONS as u64 - 1) {
            c.note_relocation(RequestId(1_000 + i), 1);
        }
        assert_eq!(c.replica_of(x), 0, "refreshed re-home outlives its tombstone");
        // One more push evicts x's LIVE entry — oldest surviving fact,
        // forgotten in order — and x resolves back to its partition.
        c.note_relocation(RequestId(999_999_999), 1);
        assert_eq!(c.replica_of(x), 1, "past the cap x resolves to id % n");
        // The map never exceeds the cap.
        assert!(c.relocated.len() <= MAX_RELOCATIONS);
    }

    #[test]
    fn failover_migration_beats_recompute_and_reports_counters() {
        // ISSUE-8 acceptance (a), long-prefix half: killing a session's
        // home with migration enabled must make the victim's next turn
        // strictly faster than the recompute path — the chain is shipped
        // to the survivor (rebuilt from the host-recoverable checkpoint,
        // DESIGN.md §18) at a modeled transfer cost instead of being
        // re-prefilled from token zero.
        let run = |migrate: bool| {
            let mut c = session_cluster(migrate);
            let mut mgr = crate::session::SessionManager::new();
            let sid = mgr.create(0);
            let t1 = mgr
                .run_turn(&mut c, sid, ModelTarget::Base, (0..2048).collect(), 16, true)
                .unwrap();
            assert_eq!(t1.cached_tokens, 0);
            let home = (mgr.get(sid).unwrap().last_request.unwrap().0 % 2) as usize;
            let report = c.fail_replica(home).unwrap();
            assert_eq!(report.orphaned_leases, vec![sid.0]);
            mgr.repair_after_failover(&mut c, &report);
            let t2 = mgr
                .run_turn(&mut c, sid, ModelTarget::Base, (3000..3032).collect(), 16, true)
                .unwrap();
            let survivor = 1 - home;
            let committed: Vec<u64> = (0..2)
                .map(|i| c.replica(i).routing_summary().committed_blocks())
                .collect();
            c.replica(survivor).check_invariants().unwrap();
            let stats = c.router().stats.clone();
            let json = c.stats().to_json().to_string();
            mgr.delete(&mut c, sid).unwrap();
            (t2.ttft_s, t2.cached_tokens, committed, stats, json, home)
        };
        let (ttft_m, cached_m, committed_m, stats_m, json_m, home_m) = run(true);
        let (ttft_r, cached_r, committed_r, stats_r, _, home_r) = run(false);
        assert_eq!(home_m, home_r, "deterministic placement across arms");
        assert!(cached_m >= 2048, "migrated chain lands warm: {cached_m}");
        assert_eq!(cached_r, 0, "recompute path starts cold");
        assert!(
            ttft_m < ttft_r,
            "migration must beat recompute: {ttft_m} vs {ttft_r}"
        );
        assert_eq!(stats_m.migrations, 1);
        assert_eq!(stats_m.migrated_blocks, 129, "2064-token chain = 129 blocks");
        assert_eq!(stats_m.migration_recompute_fallbacks, 0);
        assert_eq!(stats_r.migrations, 0);
        // ISSUE-8 satellite: fleet-wide summary totals match the
        // fresh-prefill run — migration commits exactly the hashes a
        // recompute would have, nothing extra, nothing missing.
        assert_eq!(committed_m, committed_r, "summary symmetry after migration");
        // Counters surface in the fleet document, not just Prometheus.
        assert!(json_m.contains("\"migrations\":1"), "{json_m}");
        assert!(json_m.contains("\"migrated_blocks\":129"), "{json_m}");
        assert!(json_m.contains("\"migration_recompute_fallbacks\":0"), "{json_m}");
        assert!(json_m.contains("\"session_forks\":0"), "{json_m}");
    }

    #[test]
    fn failover_migration_short_prefix_recomputes_bit_identically() {
        // ISSUE-8 acceptance (a), short-prefix half: below the cost-model
        // crossover the fixed transfer setup loses to a short prefill, so
        // the fallback must leave the serving path bit-identical to a
        // fleet with migration disabled — same cold turn, same TTFT, same
        // clock — with only the fallback counter recording the decline.
        let run = |migrate: bool| {
            let mut c = session_cluster(migrate);
            let mut mgr = crate::session::SessionManager::new();
            let sid = mgr.create(0);
            mgr.run_turn(&mut c, sid, ModelTarget::Base, (0..64).collect(), 16, true)
                .unwrap();
            let home = (mgr.get(sid).unwrap().last_request.unwrap().0 % 2) as usize;
            let report = c.fail_replica(home).unwrap();
            mgr.repair_after_failover(&mut c, &report);
            let t2 = mgr
                .run_turn(&mut c, sid, ModelTarget::Base, (900..932).collect(), 16, true)
                .unwrap();
            let stats = c.router().stats.clone();
            let clock = c.clock();
            mgr.delete(&mut c, sid).unwrap();
            (t2.ttft_s, t2.cached_tokens, clock, stats)
        };
        let (ttft_m, cached_m, clock_m, stats_m) = run(true);
        let (ttft_r, cached_r, clock_r, stats_r) = run(false);
        assert_eq!(cached_m, 0, "short chain recomputes");
        assert_eq!(cached_r, 0);
        assert_eq!(ttft_m, ttft_r, "declined migration must not perturb the sim");
        assert_eq!(clock_m, clock_r);
        assert_eq!(stats_m.migrations, 0);
        assert_eq!(stats_m.migrated_blocks, 0);
        assert_eq!(stats_m.migration_recompute_fallbacks, 1);
        assert_eq!(stats_r.migration_recompute_fallbacks, 0);
    }

    #[test]
    fn drain_migration_ships_lease_and_keeps_summaries_symmetric() {
        // Drain path: the old home still holds the pinned chain (planned
        // maintenance loses nothing), so migration does a live transfer —
        // the re-stuck turn lands warm on the new home while the lease
        // moves with it. Without the flag this is the pinned recompute
        // behavior of `sticky_turn_to_draining_replica_resticks_via_policy`.
        let run = |migrate: bool| {
            let mut c = session_cluster(migrate);
            let mut mgr = crate::session::SessionManager::new();
            let sid = mgr.create(0);
            mgr.run_turn(&mut c, sid, ModelTarget::Base, (0..2048).collect(), 16, true)
                .unwrap();
            let home = (mgr.get(sid).unwrap().last_request.unwrap().0 % 2) as usize;
            c.drain_replica(home).unwrap();
            let t2 = mgr
                .run_turn(&mut c, sid, ModelTarget::Base, (900..932).collect(), 16, true)
                .unwrap();
            let healthy = 1 - home;
            let leased =
                (c.replica(home).leased_blocks(), c.replica(healthy).leased_blocks());
            let committed: Vec<u64> = (0..2)
                .map(|i| c.replica(i).routing_summary().committed_blocks())
                .collect();
            c.replica(home).check_invariants().unwrap();
            c.replica(healthy).check_invariants().unwrap();
            let stats = c.router().stats.clone();
            mgr.delete(&mut c, sid).unwrap();
            (t2.cached_tokens, t2.ttft_s, leased, committed, stats)
        };
        let (cached_m, ttft_m, leased_m, committed_m, stats_m) = run(true);
        let (cached_r, ttft_r, leased_r, committed_r, stats_r) = run(false);
        assert!(cached_m >= 2048, "drained home's chain shipped warm: {cached_m}");
        assert_eq!(cached_r, 0, "without the flag the turn recomputes cold");
        assert!(ttft_m < ttft_r, "live transfer beats recompute");
        assert_eq!(leased_m.0, 0, "source pin released by the migration");
        assert!(leased_m.1 > 0, "destination pins the shipped chain");
        assert_eq!(leased_m, leased_r, "final lease placement identical either way");
        assert_eq!(stats_m.migrations, 1);
        assert_eq!(stats_m.resticks, 1);
        assert_eq!(stats_r.migrations, 0);
        // Summary symmetry on BOTH replicas: the drained source keeps its
        // unpinned committed copy in each arm, the destination ends up
        // with the same committed set whether installed or recomputed.
        assert_eq!(committed_m, committed_r, "fleet summaries symmetric");
    }

    #[test]
    fn default_fleet_is_bit_identical_to_a_plain_cluster() {
        // ISSUE-9 acceptance: gossip period 0 (the default) must leave
        // routing BIT-identical to the pre-gossip fleet — same
        // placements, same summary probes, same chain hashing, same
        // clock. `with_fleet` with every replica active is the same
        // machine as `from_factory`.
        let run = |fleeted: bool| {
            let mut c = if fleeted {
                let engines: Vec<_> = (0..3)
                    .map(|_| {
                        let cfg = presets::granite_8b();
                        let reg = workload::build_registry(2, cfg.model.vocab_size, true);
                        let exec = SimExecutor::new(&cfg);
                        Engine::with_registry(cfg, reg, exec)
                    })
                    .collect();
                Cluster::with_fleet(engines, RouterConfig::default(), FleetConfig::default(), 3)
                    .unwrap()
            } else {
                cluster(3, RoutePolicy::PrefixAffinity)
            };
            let vocab = c.config().model.vocab_size;
            crate::kvcache::summary::take_probe_ops();
            crate::kvcache::prefix::take_hash_ops();
            let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
            let mut ids = Vec::new();
            let mut prompts = Vec::new();
            for k in 0..6u32 {
                let prompt: Vec<u32> = (0..192).map(|t| (t * 7 + 389 * k) % vocab).collect();
                ids.push(c.submit(ModelTarget::Base, prompt.clone(), p).unwrap());
                prompts.push(prompt);
            }
            c.run_until_idle();
            let outs: std::collections::HashMap<_, _> =
                c.take_finished().into_iter().map(|o| (o.id, o)).collect();
            // Warm follow-ups exercise the affinity probes the gossip
            // layer interposes on.
            for (k, id) in ids.iter().enumerate() {
                let mut follow = prompts[k].clone();
                follow.extend(&outs[id].output_tokens);
                follow.push(7);
                c.submit(ModelTarget::Base, follow, p).unwrap();
            }
            c.run_until_idle();
            let n2 = c.take_finished().len();
            (
                c.router().stats.routed.clone(),
                c.router().stats.affinity_hits,
                crate::kvcache::summary::take_probe_ops(),
                crate::kvcache::prefix::take_hash_ops(),
                c.clock().to_bits(),
                outs.len(),
                n2,
            )
        };
        assert_eq!(run(true), run(false));
    }

    /// The shared pin for "detection runs the declared pipeline": every
    /// observable consequence of the failover — victim, requeue set,
    /// orphaned leases, drops, re-homes — must be identical whether the
    /// monitor declared the death or an operator did.
    fn assert_failover_parity(auto: &FailoverReport, declared: &FailoverReport) {
        assert_eq!(auto.replica, declared.replica, "same victim");
        assert_eq!(auto.num_replicas, declared.num_replicas);
        assert_eq!(auto.requeued, declared.requeued, "identical requeue count");
        assert_eq!(auto.orphaned_leases, declared.orphaned_leases, "identical orphans");
        assert_eq!(auto.rejected, declared.rejected, "identical drops");
        assert_eq!(auto.relocated, declared.relocated, "identical re-homes");
    }

    #[test]
    fn silence_detection_runs_the_declared_failover_pipeline() {
        // ISSUE-9 acceptance: silencing a replica mid-burst walks
        // Up → Suspected → Down in exactly `down_after_misses` steps and
        // runs the SAME pipeline `POST /cluster/replicas/{i}/fail`
        // would — with zero lost requests — and runs it exactly once.
        let run = |silence: bool| {
            let mut c = cluster(3, RoutePolicy::RoundRobin);
            let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
            // One finished conversation per replica, with a lease pinned
            // on the future victim so orphan parity is non-trivial.
            let mut victim_prompt = Vec::new();
            let mut victim_id = None;
            for k in 0..3u32 {
                let prompt: Vec<u32> = (k * 500..k * 500 + 256).collect();
                let id = c.submit(ModelTarget::Base, prompt.clone(), p).unwrap();
                if k == 1 {
                    victim_prompt = prompt;
                    victim_id = Some(id);
                }
            }
            c.run_until_idle();
            c.take_finished();
            assert_eq!((victim_id.unwrap().0 % 3) as usize, 1, "RR: k=1 → replica 1");
            let pinned = c.acquire_lease(77, &victim_prompt, 0, victim_id);
            assert!(pinned > 0, "lease pinned on the victim");
            // Mid-burst: 9 slow requests in flight, 3 per replica.
            let ids: Vec<_> = (0..9u32)
                .map(|k| {
                    c.submit(
                        ModelTarget::Base,
                        vec![100 + k; 64],
                        SamplingParams { max_new_tokens: 32, ..Default::default() },
                    )
                    .unwrap()
                })
                .collect();
            let report = if silence {
                c.silence_replica(1).unwrap();
                let mut reports = Vec::new();
                for s in 1..=6u32 {
                    c.step();
                    if s == 3 {
                        assert!(c.is_suspected(1), "suspected at suspect_after_misses");
                        assert_eq!(c.health_detail(1), "suspected(3)");
                        assert_eq!(c.router().stats.suspected_transitions, 1);
                    }
                    let r = c.take_failover_reports();
                    if s < 6 {
                        assert!(r.is_empty(), "no failover before miss {s} hits the threshold");
                    }
                    reports.extend(r);
                }
                assert_eq!(reports.len(), 1, "detection fired exactly once");
                assert_eq!(c.router().stats.heartbeat_misses, 6, "latency == down_after");
                assert_eq!(c.router().stats.detected_failures, 1);
                // More silent steps: the monitor is saturated, the
                // pipeline never re-fires.
                for _ in 0..3 {
                    c.step();
                }
                assert!(c.take_failover_reports().is_empty(), "failover runs once");
                assert_eq!(c.router().stats.replica_failures, 1);
                reports.pop().unwrap()
            } else {
                for _ in 0..6 {
                    c.step();
                }
                c.fail_replica(1).unwrap()
            };
            assert_eq!(c.health(1), ReplicaHealth::Down);
            // Zero lost requests: every mid-burst id still produces its
            // output on a survivor.
            let mut done = std::collections::HashSet::new();
            let mut guard = 0;
            while done.len() < ids.len() {
                for o in c.take_finished() {
                    if ids.contains(&o.id) {
                        done.insert(o.id);
                    }
                }
                if done.len() == ids.len() {
                    break;
                }
                guard += 1;
                assert!(guard < 10_000, "lost requests: {}/{}", done.len(), ids.len());
                c.step();
            }
            (report, c.router().stats.routed.clone())
        };
        let (auto, routed_a) = run(true);
        let (declared, routed_d) = run(false);
        assert_failover_parity(&auto, &declared);
        assert_eq!(auto.requeued, 3, "the victim's in-flight requests requeued");
        assert_eq!(auto.orphaned_leases, vec![77]);
        assert_eq!(routed_a, routed_d, "identical placements either way");
    }

    #[test]
    fn stale_gossip_snapshots_decay_affinity_toward_least_loaded() {
        // Gossip on: the router scores last-gossiped snapshots. A
        // silenced replica stops publishing; its snapshot's affinity
        // decays linearly past the staleness bound until the replica is
        // scored like a cold one (least-loaded fallback).
        let engines: Vec<_> = (0..2)
            .map(|_| {
                let cfg = presets::granite_8b();
                let reg = workload::build_registry(2, cfg.model.vocab_size, true);
                let exec = SimExecutor::new(&cfg);
                Engine::with_registry(cfg, reg, exec)
            })
            .collect();
        let fleet = FleetConfig {
            gossip_period_steps: 1,
            gossip_stale_rounds: 1,
            gossip_decay_slope: 0.25,
            // Keep the failure detector far away: this test is about
            // routing, not detection.
            suspect_after_misses: 50,
            down_after_misses: 60,
            ..FleetConfig::default()
        };
        let mut c = Cluster::with_fleet(engines, RouterConfig::default(), fleet, 2).unwrap();
        let prompt: Vec<u32> = (0..256).collect();
        let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
        // Warm replica 0 (cold fallback → first index) and let gossip
        // publish its summary.
        c.submit(ModelTarget::Base, prompt.clone(), p).unwrap();
        c.run_until_idle();
        c.take_finished();
        assert_eq!(c.router().stats.affinity_fallbacks, 1);
        // A same-prefix submission scores the gossiped snapshot: warm.
        c.submit(ModelTarget::Base, prompt.clone(), p).unwrap();
        c.run_until_idle();
        c.take_finished();
        assert_eq!(c.router().stats.affinity_hits, 1);
        assert_eq!(c.router().stats.routed, vec![2, 0]);
        // Silence replica 0: it stops publishing. Idle steps advance the
        // gossip round; the stale snapshot's score decays monotonically
        // to zero.
        c.silence_replica(0).unwrap();
        let mut last = usize::MAX;
        for _ in 0..8 {
            c.step();
            let (views, _) = c.views_for(ModelTarget::Base, &prompt, 0);
            assert!(views[0].affinity_blocks <= last, "decay is monotone");
            last = views[0].affinity_blocks;
        }
        assert_eq!(last, 0, "fully decayed past the staleness bound");
        assert!(c.router().stats.stale_sketch_decays > 0);
        // The same warm prefix now routes as cold: a fallback, not a hit.
        c.submit(ModelTarget::Base, prompt.clone(), p).unwrap();
        assert_eq!(c.router().stats.affinity_hits, 1, "no new hit: the sketch is stale");
        assert_eq!(c.router().stats.affinity_fallbacks, 2);
        c.run_until_idle();
        c.take_finished();
    }

    #[test]
    fn autoscaler_grows_under_pressure_and_shrinks_back_idle() {
        // ISSUE-9 acceptance: a burst beyond one tiny replica's capacity
        // drives sustained queue pressure → the autoscaler activates
        // standbys (cold: warming until their summary fills); when the
        // burst drains, the idle streak shrinks the fleet back to
        // `min_replicas`, with zero lost requests.
        let engines: Vec<_> = (0..3)
            .map(|_| {
                let cfg = presets::tiny();
                let reg = workload::build_registry(2, cfg.model.vocab_size, true);
                let exec = SimExecutor::new(&cfg);
                Engine::with_registry(cfg, reg, exec)
            })
            .collect();
        let fleet = FleetConfig {
            autoscale: true,
            min_replicas: 1,
            scale_up_after_steps: 2,
            scale_down_after_steps: 4,
            queue_high: 2.0,
            queue_low: 0.5,
            cooldown_steps: 2,
            warmup_min_blocks: 4,
            ..FleetConfig::default()
        };
        let rcfg = RouterConfig { policy: RoutePolicy::LeastLoaded, ..Default::default() };
        let mut c = Cluster::with_fleet(engines, rcfg, fleet, 1).unwrap();
        assert_eq!((c.num_healthy(), c.num_standby()), (1, 2));
        let p = SamplingParams { max_new_tokens: 2, ..Default::default() };
        let ids: Vec<_> = (0..40u32)
            .map(|k| c.submit(ModelTarget::Base, vec![1 + (k % 7); 32], p).unwrap())
            .collect();
        // tiny admits 8 sequences: the rest wait → sustained pressure.
        // Two streak steps fire the first activation; it comes up COLD.
        c.step();
        assert_eq!(c.num_healthy(), 1, "one pressured step is not a streak");
        c.step();
        assert_eq!(c.num_healthy(), 2, "second consecutive pressured step scales up");
        assert_eq!(c.router().stats.scale_ups, 1);
        assert_eq!(c.health_detail(1), "warming", "fresh activation is cold");
        // Queued work stays home; pressure persists through the cooldown
        // and the fleet grows to its pre-provisioned maximum.
        let mut outs = Vec::new();
        for _ in 0..6 {
            c.step();
            outs.extend(c.take_finished());
        }
        assert_eq!(c.router().stats.scale_ups, 2, "cooldown paced the second activation");
        assert_eq!(c.num_standby(), 0);
        // Overflow lands on the activated replicas (the settled replica
        // is busy), which warms them up for real.
        let more: Vec<_> = (0..12u32)
            .map(|k| c.submit(ModelTarget::Base, vec![50 + k; 32], p).unwrap())
            .collect();
        let routed = c.router().stats.routed.clone();
        assert!(routed[1] + routed[2] > 0, "activated replicas take overflow: {routed:?}");
        // Drain everything, then sit idle: the low streak retires the
        // extra replicas one at a time, back down to min_replicas.
        let mut steps = 0;
        while c.has_work() {
            c.step();
            outs.extend(c.take_finished());
            steps += 1;
            assert!(steps < 10_000, "burst never drained");
        }
        for _ in 0..40 {
            c.step();
        }
        outs.extend(c.take_finished());
        assert_eq!(c.num_healthy(), 1, "idle fleet shrank to min_replicas");
        assert_eq!(c.num_standby(), 2);
        assert_eq!(c.router().stats.scale_downs, 2);
        assert_eq!(c.stats().fleet.descaling, None);
        // Zero lost requests across the whole swing.
        let got: std::collections::HashSet<_> = outs.iter().map(|o| o.id).collect();
        assert_eq!(got.len(), ids.len() + more.len());
        for i in 0..3 {
            c.replica(i).check_invariants().unwrap();
        }
    }

    #[test]
    fn autoscale_down_waits_for_drain_and_batch_migrates_leases() {
        // ISSUE-9 acceptance: a scale-down victim retires only after its
        // in-flight turn finishes, and its leased chains ship to the
        // survivor in ONE batch (setup paid once) because the cost model
        // says migration wins at this prefix length.
        let mut c = Cluster::from_factory(2, RoutePolicy::RoundRobin, |_| {
            let mut cfg = presets::granite_8b();
            cfg.cache.prefix_migration = true;
            let reg = workload::build_registry(2, cfg.model.vocab_size, true);
            let exec = SimExecutor::new(&cfg);
            Engine::with_registry(cfg, reg, exec)
        })
        .unwrap();
        let p = SamplingParams { max_new_tokens: 4, ..Default::default() };
        // Two long conversations land on replica 1 (RR: odd submissions)
        // and pin their 64-block chains under leases.
        let pa: Vec<u32> = (0..1024).collect();
        let pb: Vec<u32> = (10_000..10_000 + 1024).collect();
        let _f0 = c.submit(ModelTarget::Base, vec![1; 64], p).unwrap(); // → 0
        let idb = c.submit(ModelTarget::Base, pa.clone(), p).unwrap(); // → 1
        let _f1 = c.submit(ModelTarget::Base, vec![2; 64], p).unwrap(); // → 0
        let idc = c.submit(ModelTarget::Base, pb.clone(), p).unwrap(); // → 1
        c.run_until_idle();
        c.take_finished();
        assert_eq!((idb.0 % 2, idc.0 % 2), (1, 1));
        let pinned_a = c.acquire_lease(41, &pa, 0, Some(idb));
        let pinned_b = c.acquire_lease(42, &pb, 0, Some(idc));
        assert!(pinned_a >= 60 && pinned_b >= 60, "{pinned_a}/{pinned_b}");
        // A long turn starts on the future victim...
        let _d0 = c.submit(ModelTarget::Base, vec![3; 64], p).unwrap(); // → 0
        let d1 = c
            .submit(
                ModelTarget::Base,
                vec![4; 64],
                SamplingParams { max_new_tokens: 64, ..Default::default() },
            )
            .unwrap(); // → 1
        // ...then the autoscaler starts shrinking: the queues are "idle"
        // (the signal is waiting depth, not running work).
        let fleet = FleetConfig {
            autoscale: true,
            min_replicas: 1,
            scale_down_after_steps: 2,
            queue_low: 10.0,
            queue_high: 20.0,
            cooldown_steps: 2,
            ..FleetConfig::default()
        };
        c.set_fleet_config(fleet).unwrap();
        let mut outs = Vec::new();
        let mut saw_draining_with_work = false;
        for _ in 0..400 {
            c.step();
            outs.extend(c.take_finished());
            if c.health(1) == ReplicaHealth::Draining && c.replica(1).has_work() {
                saw_draining_with_work = true;
                assert_eq!(c.stats().fleet.descaling, Some(1));
            }
            if !c.has_work() && c.health(1) == ReplicaHealth::Standby {
                break;
            }
        }
        assert!(saw_draining_with_work, "victim drained while a turn was in flight");
        assert!(outs.iter().any(|o| o.id == d1), "in-flight turn finished where it started");
        assert_eq!(c.health(1), ReplicaHealth::Standby);
        assert_eq!(c.router().stats.scale_downs, 1);
        // Both leased chains shipped to the survivor in one batch.
        assert_eq!(c.router().stats.migrations, 2);
        assert!(c.router().stats.migrated_blocks >= 120, "{}", c.router().stats.migrated_blocks);
        assert!(c.replica(0).lease_chain(41).is_some());
        assert!(c.replica(0).lease_chain(42).is_some());
        assert!(c.replica(1).lease_chain(41).is_none());
        assert_eq!(c.replica(1).leased_blocks(), 0, "the retired replica pins nothing");
        for i in 0..2 {
            c.replica(i).check_invariants().unwrap();
        }
    }

    #[test]
    fn from_specs_builds_heterogeneous_fleet_and_reports_tiers() {
        use crate::config::ReplicaSpec;
        let base = presets::granite_8b();
        let fleet = FleetConfig {
            replica_specs: vec![
                ReplicaSpec { max_kv_tokens: 200_704, host_adapter_blocks: 256 },
                ReplicaSpec { max_kv_tokens: 501_760, host_adapter_blocks: 0 },
            ],
            ..FleetConfig::default()
        };
        let c = Cluster::from_specs(
            2,
            &base,
            RouterConfig::default(),
            fleet,
            2,
            |_, cfg| {
                let reg = workload::build_registry(2, cfg.model.vocab_size, true);
                let exec = SimExecutor::new(&cfg);
                Engine::with_registry(cfg, reg, exec)
            },
        )
        .unwrap();
        // Capacity diverges per replica; everything else is shared.
        let s = c.stats();
        assert_eq!(s.replicas[0].total_blocks, 200_704 / 16);
        assert_eq!(s.replicas[1].total_blocks, 501_760 / 16);
        assert_eq!(s.replicas[0].host_total_blocks, 256);
        assert_eq!(s.replicas[1].host_total_blocks, 0);
        assert_eq!(s.replicas[0].adapter_host_blocks, 0, "nothing demoted yet");
        let j = s.to_json().to_string();
        assert!(j.contains("\"host_total_blocks\":256"), "{j}");
        // Views surface per-replica headroom for the cold fallback.
        let v = c.views_for(ModelTarget::Base, &[1, 2, 3], 0).0;
        assert_eq!(v[0].free_blocks, 200_704 / 16);
        assert_eq!(v[1].free_blocks, 501_760 / 16);
    }

    #[test]
    fn divergence_beyond_capacity_is_still_rejected() {
        let mk = |aligned: bool| {
            let mut cfg = presets::granite_8b();
            cfg.cache.base_aligned_hashing = aligned;
            let reg = workload::build_registry(2, cfg.model.vocab_size, true);
            let exec = SimExecutor::new(&cfg);
            Engine::with_registry(cfg, reg, exec)
        };
        let err = Cluster::new(vec![mk(true), mk(false)], RoutePolicy::PrefixAffinity)
            .unwrap_err()
            .to_string();
        assert!(err.contains("beyond capacity"), "{err}");
        // But capacity-only divergence is fine without from_specs too.
        let bigger = |grow: bool| {
            let mut cfg = presets::granite_8b();
            if grow {
                cfg.cache.max_kv_tokens *= 2;
            }
            let reg = workload::build_registry(2, cfg.model.vocab_size, true);
            let exec = SimExecutor::new(&cfg);
            Engine::with_registry(cfg, reg, exec)
        };
        assert!(Cluster::new(vec![bigger(false), bigger(true)], RoutePolicy::PrefixAffinity)
            .is_ok());
    }
}
