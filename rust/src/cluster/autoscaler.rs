//! Fleet autoscaler control loop (DESIGN.md §19).
//!
//! A pure, deterministic controller over the signals the cluster already
//! exports: total queue depth across active replicas (normalized per
//! replica) and KV-pool pressure against the admission watermark. It
//! decides *when* to scale; the cluster decides *how* (activate the
//! lowest-index standby, or drain the highest-index active replica and
//! batch-migrate its leases — see `Cluster::step`).
//!
//! Invariants the controller enforces by construction:
//! - never a decision during cooldown (streaks keep accumulating, so a
//!   sustained condition fires on the first post-cooldown step);
//! - scale-up requires `scale_up_after_steps` *consecutive* pressured
//!   steps, scale-down `scale_down_after_steps` consecutive idle steps —
//!   one calm step resets the streak;
//! - scale-up wins ties (pressure is never answered by shrinking);
//! - the fleet stays within `[min_replicas, max]` — `max` is the number
//!   of pre-provisioned engines, fixed at construction so request-id
//!   striping never changes.

use crate::config::FleetConfig;

/// One step's worth of fleet signals, gathered by the cluster.
#[derive(Debug, Clone, Copy, Default)]
pub struct ScaleSignals {
    /// Active (routable, `Up`) replicas, including warming ones.
    pub active_replicas: usize,
    /// Whether any standby replica is available to activate.
    pub standby_available: bool,
    /// Total waiting (queued, unadmitted) requests across active replicas.
    pub waiting: usize,
    /// Worst per-replica KV-pool usage fraction (1 - free/total).
    pub kv_pressure: f64,
    /// The engines' configured admission watermark: pool pressure at or
    /// above it means admissions are about to stall.
    pub admission_watermark: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// Activate one standby replica.
    Up,
    /// Drain one active replica toward standby.
    Down,
}

#[derive(Debug, Clone)]
pub struct Autoscaler {
    cfg: FleetConfig,
    high_streak: u32,
    low_streak: u32,
    cooldown: u32,
}

impl Autoscaler {
    pub fn new(cfg: FleetConfig) -> Self {
        Autoscaler { cfg, high_streak: 0, low_streak: 0, cooldown: 0 }
    }

    /// Feed one step's signals; returns at most one scale decision. The
    /// caller must call [`Autoscaler::note_scaled`] once it actually
    /// executes a decision (activation succeeded / drain began), which
    /// starts the cooldown and clears both streaks.
    pub fn observe(&mut self, s: &ScaleSignals) -> ScaleDecision {
        if s.active_replicas == 0 {
            return ScaleDecision::Hold;
        }
        let queue_per_replica = s.waiting as f64 / s.active_replicas as f64;
        let pressured = queue_per_replica > self.cfg.queue_high
            || s.kv_pressure >= s.admission_watermark;
        let idle = queue_per_replica < self.cfg.queue_low
            && s.kv_pressure < s.admission_watermark;
        self.high_streak = if pressured { self.high_streak + 1 } else { 0 };
        self.low_streak = if idle { self.low_streak + 1 } else { 0 };

        if self.cooldown > 0 {
            self.cooldown -= 1;
            return ScaleDecision::Hold;
        }
        if self.high_streak >= self.cfg.scale_up_after_steps && s.standby_available {
            return ScaleDecision::Up;
        }
        if self.low_streak >= self.cfg.scale_down_after_steps
            && s.active_replicas > self.cfg.min_replicas
        {
            return ScaleDecision::Down;
        }
        ScaleDecision::Hold
    }

    /// A decision was executed: start the cooldown, clear the streaks.
    pub fn note_scaled(&mut self) {
        self.cooldown = self.cfg.cooldown_steps;
        self.high_streak = 0;
        self.low_streak = 0;
    }

    pub fn cooldown_remaining(&self) -> u32 {
        self.cooldown
    }

    pub fn high_streak(&self) -> u32 {
        self.high_streak
    }

    pub fn low_streak(&self) -> u32 {
        self.low_streak
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FleetConfig {
        FleetConfig {
            autoscale: true,
            min_replicas: 1,
            scale_up_after_steps: 3,
            scale_down_after_steps: 4,
            queue_high: 4.0,
            queue_low: 0.5,
            cooldown_steps: 5,
            ..FleetConfig::default()
        }
    }

    fn pressured(active: usize) -> ScaleSignals {
        ScaleSignals {
            active_replicas: active,
            standby_available: true,
            waiting: active * 10, // 10 per replica >> queue_high
            kv_pressure: 0.2,
            admission_watermark: 0.9,
        }
    }

    fn idle(active: usize) -> ScaleSignals {
        ScaleSignals {
            active_replicas: active,
            standby_available: true,
            waiting: 0,
            kv_pressure: 0.1,
            admission_watermark: 0.9,
        }
    }

    #[test]
    fn scale_up_needs_a_sustained_streak() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(&pressured(2)), ScaleDecision::Hold);
        assert_eq!(a.observe(&pressured(2)), ScaleDecision::Hold);
        // One calm step resets the streak entirely.
        assert_eq!(a.observe(&idle(2)), ScaleDecision::Hold);
        assert_eq!(a.observe(&pressured(2)), ScaleDecision::Hold);
        assert_eq!(a.observe(&pressured(2)), ScaleDecision::Hold);
        assert_eq!(a.observe(&pressured(2)), ScaleDecision::Up, "3rd consecutive");
    }

    #[test]
    fn kv_pressure_alone_triggers_scale_up() {
        let mut a = Autoscaler::new(cfg());
        let s = ScaleSignals {
            active_replicas: 2,
            standby_available: true,
            waiting: 0, // queues empty, but the pool is nearly full
            kv_pressure: 0.95,
            admission_watermark: 0.9,
        };
        for _ in 0..2 {
            assert_eq!(a.observe(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.observe(&s), ScaleDecision::Up);
    }

    #[test]
    fn no_scale_up_without_standby_capacity() {
        let mut a = Autoscaler::new(cfg());
        let mut s = pressured(4);
        s.standby_available = false;
        for _ in 0..20 {
            assert_eq!(a.observe(&s), ScaleDecision::Hold);
        }
    }

    #[test]
    fn scale_down_respects_min_replicas() {
        let mut a = Autoscaler::new(cfg());
        for _ in 0..3 {
            assert_eq!(a.observe(&idle(2)), ScaleDecision::Hold);
        }
        assert_eq!(a.observe(&idle(2)), ScaleDecision::Down, "4th consecutive");
        // At the floor the same idle stream holds forever.
        let mut a = Autoscaler::new(cfg());
        for _ in 0..20 {
            assert_eq!(a.observe(&idle(1)), ScaleDecision::Hold);
        }
    }

    #[test]
    fn cooldown_blocks_decisions_but_streaks_accumulate() {
        let mut a = Autoscaler::new(cfg());
        for _ in 0..2 {
            a.observe(&pressured(2));
        }
        assert_eq!(a.observe(&pressured(2)), ScaleDecision::Up);
        a.note_scaled();
        assert_eq!(a.cooldown_remaining(), 5);
        // 5 cooldown steps: pressure persists but decisions hold.
        for _ in 0..5 {
            assert_eq!(a.observe(&pressured(3)), ScaleDecision::Hold);
        }
        // Streak (now 5 >= 3) fires on the first post-cooldown step.
        assert_eq!(a.observe(&pressured(3)), ScaleDecision::Up);
    }

    #[test]
    fn empty_fleet_and_middling_load_hold() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(&ScaleSignals::default()), ScaleDecision::Hold);
        // Between the watermarks: neither streak moves.
        let s = ScaleSignals {
            active_replicas: 2,
            standby_available: true,
            waiting: 4, // 2 per replica: above low, below high
            kv_pressure: 0.2,
            admission_watermark: 0.9,
        };
        for _ in 0..50 {
            assert_eq!(a.observe(&s), ScaleDecision::Hold);
        }
        assert_eq!(a.high_streak(), 0);
        assert_eq!(a.low_streak(), 0);
    }
}
