//! alora-serve CLI — leader entrypoint.
//!
//! Subcommands:
//!   figure   --id <table1|fig6..fig15|all> [--quick]       reproduce paper tables/figures
//!   pipeline --kind <base-adapter|adapter-base|base-adapter-base|multi-adapter>
//!            [--model granite-8b] [--prompt-len 1024] [--base-gen 256]
//!            [--eval-gen 16] [--batch N] [--lora]           run one pipeline, print metrics
//!   serve    [--preset granite-8b] [--addr 127.0.0.1:8471] [--real]
//!            [--replicas N] [--route affinity|rr|least-loaded|adapter]
//!            [--adapter-paging]
//!            start the HTTP server (--real loads artifacts/ via PJRT;
//!            --replicas > 1 serves a routed simulator cluster;
//!            --adapter-paging pages adapter weights against the KV
//!            block budget, DESIGN.md §13). Serves the conversation-first
//!            v1 API (/v1/sessions, per-turn adapter activation,
//!            streaming token events — see API.md) plus the legacy
//!            /generate + /pipeline endpoints.
//!   info     print presets and build info
//!
//! (Arg parsing is hand-rolled — no clap in the offline build.)

use std::collections::HashMap;

use alora_serve::adapter::AdapterId;
use alora_serve::cluster::{Cluster, RoutePolicy};
use alora_serve::config::presets;
use alora_serve::engine::Engine;
use alora_serve::figures;
use alora_serve::pipeline::{self, workload, PipelineKind, PipelineSpec};
use alora_serve::runtime::{RealExecutor, TinyModel};
use alora_serve::server::Server;
use alora_serve::simulator::SimExecutor;

fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    (flags, positional)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &args[..] } else { &args[1..] };
    let (flags, _pos) = parse_flags(rest);

    match cmd {
        "figure" => {
            let id = flags.get("id").map(String::as_str).unwrap_or("all");
            let quick = flags.contains_key("quick");
            let out_dir = flags.get("out").map(std::path::PathBuf::from);
            for table in figures::run_by_id(id, quick) {
                table.print();
                if let Some(dir) = &out_dir {
                    table.save(dir)?;
                    println!("  -> saved {}/{}.{{csv,json}}", dir.display(), table.id);
                }
            }
        }
        "trace" => {
            // trace --synthesize N --rate R --out path | trace --replay path [--lora]
            if let Some(path) = flags.get("replay") {
                let trace = alora_serve::pipeline::trace::Trace::load(std::path::Path::new(path))?;
                let alora = !flags.contains_key("lora");
                let mut engine = {
                    let mut cfg = presets::granite_8b();
                    cfg.cache.base_aligned_hashing = alora;
                    let reg = workload::build_registry(3, cfg.model.vocab_size, alora);
                    let exec = SimExecutor::new(&cfg);
                    Engine::with_registry(cfg, reg, exec)
                };
                let outs = alora_serve::pipeline::trace::replay(&mut engine, &trace);
                println!(
                    "replayed {} requests ({}) in {:.3}s virtual time",
                    outs.len(),
                    if alora { "aLoRA" } else { "LoRA baseline" },
                    engine.clock()
                );
                for (k, v) in engine.metrics.summary() {
                    println!("  {k:>20}: {v:.6}");
                }
            } else {
                let n = flags.get("synthesize").and_then(|v| v.parse().ok()).unwrap_or(50);
                let rate = flags.get("rate").and_then(|v| v.parse().ok()).unwrap_or(4.0);
                let out = flags
                    .get("out")
                    .cloned()
                    .unwrap_or_else(|| "trace.json".to_string());
                let t = alora_serve::pipeline::trace::Trace::synthesize(
                    n, rate, 512, 64, 16, 49_155, 42,
                );
                t.save(std::path::Path::new(&out))?;
                println!("wrote {} entries to {out}", t.len());
            }
        }
        "pipeline" => {
            let model = flags.get("model").map(String::as_str).unwrap_or("granite-8b");
            let kind = match flags.get("kind").map(String::as_str).unwrap_or("base-adapter") {
                "base-adapter" => PipelineKind::BaseAdapter,
                "adapter-base" => PipelineKind::AdapterBase,
                "base-adapter-base" => PipelineKind::BaseAdapterBase,
                "multi-adapter" => PipelineKind::MultiAdapter,
                other => anyhow::bail!("unknown pipeline kind `{other}`"),
            };
            let get =
                |k: &str, d: usize| flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(d);
            let n_adapters: u32 = if kind == PipelineKind::MultiAdapter { 5 } else { 1 };
            let spec = PipelineSpec {
                kind,
                prompt_len: get("prompt-len", 1024),
                base_gen: get("base-gen", 256) as u32,
                eval_gen: get("eval-gen", 16) as u32,
                adapters: (0..n_adapters).map(AdapterId).collect(),
                base2_gen: get("base2-gen", 16) as u32,
                priority_continuations: false,
            };
            let alora = !flags.contains_key("lora");
            let mut cfg = presets::by_name(model)
                .ok_or_else(|| anyhow::anyhow!("unknown preset `{model}`"))?;
            cfg.cache.base_aligned_hashing = alora;
            let batch = get(
                "batch",
                workload::batch_size_for(&cfg, spec.max_total_len()).min(16),
            );
            let reg = workload::build_registry(n_adapters, cfg.model.vocab_size, alora);
            let exec = SimExecutor::new(&cfg);
            let mut engine = Engine::with_registry(cfg, reg, exec);
            println!(
                "running {kind:?} on {model} ({}): prompt {} gen {} eval {} batch {batch}",
                if alora { "aLoRA" } else { "LoRA baseline" },
                spec.prompt_len,
                spec.base_gen,
                spec.eval_gen,
            );
            let result = pipeline::run_sync(&mut engine, &spec, batch, 42);
            let ev = result.eval_latencies();
            println!("\neval step over {} requests:", ev.count());
            for stage in ["e2e", "queue", "prefill", "decode", "ttft", "itl"] {
                println!("  {stage:>8}: {:>10.4}s", ev.mean(stage));
            }
            println!("  hit rate: {:>9.2}%", result.eval_hit_rate() * 100.0);
            println!("  makespan: {:>10.4}s", result.makespan);
            println!("\nengine metrics summary:");
            for (k, v) in engine.metrics.summary() {
                println!("  {k:>20}: {v:.6}");
            }
        }
        "serve" => {
            let addr = flags
                .get("addr")
                .cloned()
                .unwrap_or_else(|| "127.0.0.1:8471".to_string());
            if flags.contains_key("real") {
                // Fail fast rather than silently serving a single engine
                // when fleet flags are given: the real runtime has no
                // cluster mode yet (one PJRT artifact, one executor).
                anyhow::ensure!(
                    !flags.contains_key("replicas")
                        && !flags.contains_key("route")
                        && !flags.contains_key("adapter-paging"),
                    "--real serves a single always-resident engine; --replicas/--route/--adapter-paging apply to simulated serving only"
                );
                let dir = TinyModel::default_dir();
                anyhow::ensure!(
                    TinyModel::artifacts_present(&dir),
                    "artifacts missing at {} — run `make artifacts`",
                    dir.display()
                );
                let exec = RealExecutor::load(&dir, 0)?;
                let m = exec.manifest().clone();
                let cfg = presets::tiny();
                let reg = alora_serve::adapter::AdapterRegistry::tiny_default(
                    m.n_adapters as u32,
                    m.vocab_size as u32,
                    m.invocation_tokens[0].len() as u32,
                );
                let engine = Engine::with_registry(cfg, reg, exec);
                let srv = Server::start(engine, &addr)?;
                println!("serving REAL tiny model on http://{}", srv.addr());
                park_forever(srv)?;
            } else {
                let preset = flags.get("preset").map(String::as_str).unwrap_or("granite-8b");
                let replicas: usize = match flags.get("replicas") {
                    None => 1,
                    Some(v) => v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--replicas must be an integer, got `{v}`"))?,
                };
                anyhow::ensure!(replicas >= 1, "--replicas must be >= 1");
                let adapter_paging = flags.contains_key("adapter-paging");
                let mk_engine = || -> anyhow::Result<Engine<SimExecutor>> {
                    let mut cfg = presets::by_name(preset)
                        .ok_or_else(|| anyhow::anyhow!("unknown preset `{preset}`"))?;
                    cfg.cache.adapter_paging = adapter_paging;
                    let reg = workload::build_registry(3, cfg.model.vocab_size, true);
                    let exec = SimExecutor::new(&cfg);
                    Ok(Engine::with_registry(cfg, reg, exec))
                };
                // An explicit --route with one replica still gets the
                // cluster wrapper (routing a fleet of 1 is valid and keeps
                // GET /cluster available) instead of silently dropping it.
                if replicas > 1 || flags.contains_key("route") {
                    let route = flags.get("route").map(String::as_str).unwrap_or("affinity");
                    let policy = RoutePolicy::parse(route)
                        .ok_or_else(|| anyhow::anyhow!("unknown route policy `{route}`"))?;
                    let mut engines = Vec::with_capacity(replicas);
                    for _ in 0..replicas {
                        engines.push(mk_engine()?);
                    }
                    let cluster = Cluster::new(engines, policy)?;
                    let srv = Server::start(cluster, &addr)?;
                    println!(
                        "serving SIMULATED {preset} ×{replicas} ({} routing) on http://{}",
                        policy.name(),
                        srv.addr()
                    );
                    park_forever(srv)?;
                } else {
                    let srv = Server::start(mk_engine()?, &addr)?;
                    println!("serving SIMULATED {preset} on http://{}", srv.addr());
                    park_forever(srv)?;
                }
            }
        }
        "info" => {
            println!(
                "alora-serve {} — cross-model KV-cache reuse via Activated LoRA",
                env!("CARGO_PKG_VERSION")
            );
            println!("presets:");
            for name in presets::PRESET_NAMES {
                let c = presets::by_name(name).unwrap();
                println!(
                    "  {name:>16}: {:>6.2}B params, {} GPU(s), {} KV tokens, block {}",
                    c.model.n_params / 1e9,
                    c.gpu.n_gpus,
                    c.cache.max_kv_tokens,
                    c.cache.block_size
                );
            }
            let dir = TinyModel::default_dir();
            println!(
                "artifacts: {} ({})",
                dir.display(),
                if TinyModel::artifacts_present(&dir) {
                    "present"
                } else {
                    "MISSING — run `make artifacts`"
                }
            );
        }
        _ => {
            println!("usage: alora-serve <figure|pipeline|serve|info> [flags]");
            println!("  figure   --id <table1|fig6|...|fig15|all> [--quick]");
            println!("  pipeline --kind <base-adapter|adapter-base|base-adapter-base|multi-adapter> [--model M] [--prompt-len N] [--lora]");
            println!("  serve    [--preset granite-8b] [--addr host:port] [--real] [--replicas N] [--route affinity|rr|least-loaded|adapter] [--adapter-paging]");
            println!("           serves /v1/sessions (delta turns, per-turn adapter, SSE streaming; API.md) + legacy /generate, /pipeline");
            println!("  info");
        }
    }
    Ok(())
}

fn park_forever<D: alora_serve::engine::EngineDriver + Send + 'static>(
    srv: Server<D>,
) -> anyhow::Result<()> {
    let _srv = srv;
    loop {
        std::thread::park();
    }
}
