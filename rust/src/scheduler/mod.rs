//! Continuous-batching scheduler with chunked prefill and recompute
//! preemption — the vLLM substrate the paper's system runs inside (§2.4,
//! §2.5).
//!
//! Each engine step the scheduler packs one batch under a shared token
//! budget (`max_batch_tokens`): running requests first (decodes cost one
//! token; unfinished prefills take a chunk of the remaining budget — that
//! interleaving is chunked prefill, Agrawal et al. 2023), then it admits
//! waiting requests while budget and KV blocks remain. Admission consults
//! the prefix cache: whatever chain prefix hits is skipped entirely —
//! with base-aligned hashing that includes blocks prefilled by *other*
//! models, which is where the paper's latency savings enter.

use std::collections::VecDeque;

use crate::util::fxmap::FxHashMap;

use crate::adapter::residency::AdmitGate;
use crate::adapter::AdapterResidency;
use crate::config::SchedulerConfig;
use crate::kvcache::chain::ChainRef;
use crate::kvcache::manager::KvCacheManager;
use crate::kvcache::prefix::block_hashes;
use crate::request::{Request, RequestId, State};

/// One request's slice of a scheduled step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScheduledSeq {
    pub id: RequestId,
    /// First token index whose KV this chunk computes (= num_computed).
    pub chunk_start: usize,
    /// Number of tokens computed this step (1 for pure decode).
    pub chunk_len: usize,
    /// True when this chunk completes the request's current target length
    /// and therefore samples an output token.
    pub produces_token: bool,
    /// True when the request is past prefill (token-by-token generation).
    pub is_decode: bool,
}

/// The batch for one engine step.
#[derive(Debug, Clone, Default)]
pub struct ScheduledStep {
    pub seqs: Vec<ScheduledSeq>,
    /// Requests preempted while forming this batch (already re-queued).
    pub preempted: Vec<RequestId>,
    /// Requests newly admitted from the waiting queue this step.
    pub admitted: Vec<RequestId>,
    /// Total new tokens computed this step (sum of chunk_len).
    pub total_tokens: usize,
    /// New KV blocks allocated while packing this step.
    pub new_blocks: usize,
}

impl ScheduledStep {
    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn num_prefill_tokens(&self) -> usize {
        self.seqs.iter().filter(|s| !s.is_decode).map(|s| s.chunk_len).sum()
    }

    pub fn num_decode_seqs(&self) -> usize {
        self.seqs.iter().filter(|s| s.is_decode).count()
    }
}

#[derive(Debug)]
pub struct Scheduler {
    cfg: SchedulerConfig,
    waiting: VecDeque<RequestId>,
    running: Vec<RequestId>,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Scheduler { cfg, waiting: VecDeque::new(), running: Vec::new() }
    }

    pub fn num_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Enqueue a new (or preempted) request.
    pub fn enqueue(&mut self, id: RequestId, front: bool) {
        if front {
            self.waiting.push_front(id);
        } else {
            self.waiting.push_back(id);
        }
    }

    /// Remove a finished request from the running set.
    pub fn finish(&mut self, id: RequestId) {
        self.running.retain(|r| *r != id);
    }

    /// Take every queued request out of the scheduler (replica failover:
    /// the engine evacuates them for requeue elsewhere). Returns
    /// (running, waiting), each in its current order — running in
    /// admission order, waiting front-to-back — so the caller can
    /// preserve FCFS when resubmitting.
    pub fn drain_all(&mut self) -> (Vec<RequestId>, Vec<RequestId>) {
        (
            std::mem::take(&mut self.running),
            std::mem::take(&mut self.waiting).into_iter().collect(),
        )
    }

    /// Pack one step. Mutates request progress fields (`num_computed_tokens`
    /// is NOT advanced here — the engine advances it after execution), the
    /// KV manager's block tables, and adapter residency (loads at
    /// admission, ref releases on preemption). `now` is the engine's sim
    /// clock: adapter-weight transfers started here complete at
    /// `now + transfer_time` (instantaneous under the default zero-cost
    /// config, where `now` is inert).
    pub fn schedule(
        &mut self,
        reqs: &mut FxHashMap<RequestId, Request>,
        kv: &mut KvCacheManager,
        residency: &mut AdapterResidency,
        now: f64,
    ) -> ScheduledStep {
        let mut step = ScheduledStep::default();
        let mut budget = self.cfg.max_batch_tokens as usize;
        let free_before = kv.num_free_blocks();
        let adapter_before = kv.budget().adapter_blocks();
        // FCFS re-queue bookkeeping for same-step victims (see `preempt`):
        // the running order as of the FIRST preemption (still the
        // step-start order — only preemption removes entries) gives each
        // victim a stable admission rank, immune to index shifts as the
        // list shrinks; `victim_ranks` mirrors the waiting-queue front.
        let mut start_order: Option<Vec<RequestId>> = None;
        let mut victim_ranks: Vec<usize> = Vec::new();

        // ---- phase 1: running requests (decode priority = FCFS order) ----
        let mut idx = 0;
        'running: while idx < self.running.len() {
            if budget == 0 {
                break;
            }
            let id = self.running[idx];
            let (want, chunk_start, is_decode, total_len) = {
                let r = &reqs[&id];
                let want = r.total_len() - r.num_computed_tokens;
                (want, r.num_computed_tokens, !r.is_prefilling(), r.total_len())
            };
            debug_assert!(want >= 1, "running request with nothing to compute");
            let chunk = want.min(budget);

            // Grow the block table. Under pressure, reclaim from the
            // unified budget cheapest-first: an idle adapter's weight
            // pages cost nothing to drop (no recompute), so they go
            // before any request is preempted from the back.
            while !kv.ensure_capacity(id.0, chunk_start + chunk) {
                if residency.evict_one_idle(kv) {
                    continue;
                }
                let victim = *self.running.last().expect("running nonempty");
                let order =
                    start_order.get_or_insert_with(|| self.running.clone());
                let rank = order
                    .iter()
                    .position(|r| *r == victim)
                    .expect("victim unknown at step start");
                self.preempt(victim, rank, reqs, kv, residency, &mut step, &mut victim_ranks);
                if victim == id {
                    // Preempted ourselves: nothing schedulable here.
                    continue 'running; // idx now points at next (list shrank)
                }
            }

            budget -= chunk;
            step.seqs.push(ScheduledSeq {
                id,
                chunk_start,
                chunk_len: chunk,
                produces_token: chunk_start + chunk == total_len,
                is_decode,
            });
            step.total_tokens += chunk;
            idx += 1;
        }

        // ---- phase 2: admission from the waiting queue --------------------
        while budget > 0
            && self.running.len() < self.cfg.max_num_seqs as usize
            && !self.waiting.is_empty()
        {
            let id = *self.waiting.front().unwrap();
            let target = reqs[&id].target;
            // KV-pressure admission control (paper §4.3): defer admission if
            // this request's *final* length would push projected block usage
            // past the watermark — admitting it anyway would evict reusable
            // cache blocks and destroy the aLoRA speedup (Figure 9 droop).
            // The projection runs on the UNIFIED budget: in-use blocks
            // already include resident adapter weights, and the demand adds
            // the candidate's pending weight-load cost on top of its KV.
            if self.cfg.admission_watermark < 1.0 {
                let r = &reqs[&id];
                let demand = r.final_len().div_ceil(kv.block_size())
                    + residency.pending_load_blocks(target.adapter());
                // Session-leased blocks are reclaimable on demand (the
                // allocation path breaks leases before failing), so the
                // projection must not let parked sessions defer admission
                // — a lease breaks BEFORE any admission stall (DESIGN.md
                // §14.2). Distinct count: a pin shared with a running
                // request stays in-use either way, so subtracting it errs
                // toward admission, which ensure_capacity's reclaim
                // backstops.
                let in_use = ((kv.num_total_blocks() - kv.num_free_blocks()) as usize)
                    .saturating_sub(kv.leased_distinct_blocks());
                let limit =
                    (self.cfg.admission_watermark * kv.num_total_blocks() as f64) as usize;
                if in_use + demand > limit && !self.running.is_empty() {
                    break; // wait for running work to drain
                }
            }
            // Adapter-residency gate: admission needs the adapter's weights
            // on-device and READY. A load may evict idle adapters and cold
            // cached blocks — never a running request's blocks. Two stall
            // shapes, both FCFS (DESIGN.md §20): memory not reclaimable
            // yet (wait for running work to drain or a preemption to drop
            // a ref), or the weight transfer is still in flight (wait for
            // the sim clock to pass its completion).
            let was_resident = match target.adapter() {
                None => true,
                Some(aid) => match residency.admission_gate(aid, kv, now) {
                    AdmitGate::Hit => !reqs[&id].admission_cold_load,
                    AdmitGate::LoadedNow => {
                        // Remember the cold load on the request itself: if
                        // the capacity check below rolls this admission
                        // back, the retry next step finds the adapter
                        // resident but must still count as a cold
                        // admission — this request paid for the load.
                        reqs.get_mut(&id).unwrap().admission_cold_load = true;
                        false
                    }
                    AdmitGate::Loading(_) => {
                        // Transfer started (or already in flight) for this
                        // request: a cold admission once it matures.
                        reqs.get_mut(&id).unwrap().admission_cold_load = true;
                        residency.note_stall();
                        break;
                    }
                    AdmitGate::NoMemory => {
                        residency.note_stall();
                        break;
                    }
                },
            };
            let admitted_ok = {
                let r = reqs.get_mut(&id).expect("unknown waiting request");
                debug_assert!(matches!(r.state, State::Waiting | State::Preempted));
                // (Re)build the hash chain over the full token stream —
                // unless an existing chain (cluster-router pre-seed, or
                // progress kept across preemption) already covers every
                // full block: entries are deterministic in (tokens,
                // salting context), so a full-length chain is identical
                // to what a rebuild would produce.
                let tokens = r.all_tokens();
                if r.hash_chain.len() < tokens.len() / kv.block_size() {
                    r.hash_chain = ChainRef::from_hashes(&block_hashes(
                        &tokens,
                        kv.block_size(),
                        &r.hash_ctx,
                    ));
                }
                // At least one token must be computed to produce logits:
                // cap usable cached blocks below the full stream length.
                let max_usable_blocks = (r.total_len() - 1) / kv.block_size();
                let usable = r.hash_chain.len().min(max_usable_blocks);
                let cached =
                    kv.start_request(id.0, &r.hash_chain.prefix(usable), r.total_len());
                r.num_cached_tokens = cached.tokens;
                r.num_computed_tokens = cached.tokens;
                let want = r.total_len() - r.num_computed_tokens;
                let chunk = want.min(budget);
                // Same unified-reclaim order as phase 1: idle adapter
                // pages (excluding the one just loaded for this request)
                // are dropped before giving up on the allocation.
                let fits = loop {
                    if kv.ensure_capacity(id.0, r.num_computed_tokens + chunk) {
                        break true;
                    }
                    if !residency.evict_one_idle_except(kv, target.adapter()) {
                        break false;
                    }
                };
                if fits {
                    let seq = ScheduledSeq {
                        id,
                        chunk_start: r.num_computed_tokens,
                        chunk_len: chunk,
                        produces_token: r.num_computed_tokens + chunk == r.total_len(),
                        is_decode: false,
                    };
                    r.state = State::Running;
                    budget -= chunk;
                    step.seqs.push(seq);
                    step.total_tokens += chunk;
                    true
                } else {
                    // No room: roll back admission, stop admitting.
                    kv.free_request(id.0);
                    r.num_cached_tokens = 0;
                    r.num_computed_tokens = 0;
                    false
                }
            };
            if admitted_ok {
                self.waiting.pop_front();
                self.running.push(id);
                step.admitted.push(id);
                // The admission holds its adapter from now until finish or
                // preemption; count the admission against the residency
                // hit-rate (warm iff this request never triggered the
                // load — a later re-admission after preemption may then
                // legitimately find the weights warm).
                if let Some(aid) = target.adapter() {
                    residency.acquire(aid, was_resident);
                    reqs.get_mut(&id).unwrap().admission_cold_load = false;
                }
            } else {
                break;
            }
        }

        // ---- phase 3: prefetch (DESIGN.md §20) ----------------------------
        // Overlap a queued request's cold adapter transfer with its queue
        // wait: scan front-to-back for the first cold adapter and start at
        // most ONE transfer per step (bounded and deterministic; the claim
        // may LRU-evict idle adapters but a failure is quiet — the request
        // wasn't admissible this step anyway). No-op unless
        // `cache.adapter_prefetch` is set AND loads have a modeled cost.
        if residency.prefetch_enabled() {
            for id in &self.waiting {
                if let Some(aid) = reqs[id].target.adapter() {
                    if !residency.is_resident(aid) {
                        residency.try_prefetch(aid, kv, now);
                        break;
                    }
                }
            }
        }

        // KV blocks newly allocated this step — adapter weight pages
        // claimed/released while packing are excluded: their cost is
        // charged by the residency transfer state machine (DESIGN.md §20;
        // zero under the default config), so they must not also feed the
        // simulator's per-block allocation cost.
        let total = kv.num_total_blocks() as usize;
        let kv_in_use_before =
            total - free_before as usize - adapter_before;
        let kv_in_use_after =
            total - kv.num_free_blocks() as usize - kv.budget().adapter_blocks();
        step.new_blocks = kv_in_use_after.saturating_sub(kv_in_use_before);
        step
    }

    fn preempt(
        &mut self,
        victim: RequestId,
        admit_rank: usize,
        reqs: &mut FxHashMap<RequestId, Request>,
        kv: &mut KvCacheManager,
        residency: &mut AdapterResidency,
        step: &mut ScheduledStep,
        victim_ranks: &mut Vec<usize>,
    ) {
        let pos = self
            .running
            .iter()
            .rposition(|r| *r == victim)
            .expect("victim not running");
        self.running.remove(pos);
        // Drop any chunk already packed for the victim this step.
        if let Some(i) = step.seqs.iter().position(|s| s.id == victim) {
            let s = step.seqs.remove(i);
            step.total_tokens -= s.chunk_len;
        }
        kv.preempt_request(victim.0);
        let r = reqs.get_mut(&victim).unwrap();
        // Preempting the last request using an adapter drops its ref, so
        // the adapter becomes LRU-evictable — reclaimable memory for
        // whatever triggered the preemption.
        if let crate::request::ModelTarget::Adapter(aid) = r.target {
            residency.release(aid);
        }
        r.reset_for_recompute();
        // Re-queue the step's victims ahead of the pre-existing queue but
        // in their original FCFS (admission) order, not preemption order:
        // victims are picked newest-first, so a bare push_front happens to
        // work today, but the ordering contract is FCFS, and keying on the
        // step-start rank (not a shrinking-list index) makes it hold for
        // any victim-selection policy. `victim_ranks` mirrors the queue
        // front: same-step victims sorted ascending by admission rank.
        let insert_at = victim_ranks.iter().filter(|&&p| p < admit_rank).count();
        self.waiting.insert(insert_at, victim);
        victim_ranks.insert(insert_at, admit_rank);
        step.preempted.push(victim);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerConfig;
    use crate::kvcache::manager::KvCacheManager;
    use crate::request::{ModelTarget, SamplingParams};

    fn cfg(budget: u32, max_seqs: u32) -> SchedulerConfig {
        SchedulerConfig {
            max_batch_tokens: budget,
            max_num_seqs: max_seqs,
            max_seq_len: 4096,
            admission_watermark: 1.0,
        }
    }

    fn mk_req(id: u64, prompt_len: usize, max_new: u32) -> Request {
        Request::new(
            RequestId(id),
            ModelTarget::Base,
            (0..prompt_len as u32).collect(),
            SamplingParams { max_new_tokens: max_new, ..Default::default() },
            0.0,
        )
    }

    struct Fixture {
        sched: Scheduler,
        reqs: FxHashMap<RequestId, Request>,
        kv: KvCacheManager,
        residency: AdapterResidency,
    }

    fn fixture(budget: u32, max_seqs: u32, blocks: u32) -> Fixture {
        Fixture {
            sched: Scheduler::new(cfg(budget, max_seqs)),
            reqs: FxHashMap::default(),
            kv: KvCacheManager::new(blocks, 16, true),
            residency: AdapterResidency::disabled(),
        }
    }

    impl Fixture {
        fn submit(&mut self, r: Request) {
            let id = r.id;
            self.reqs.insert(id, r);
            self.sched.enqueue(id, false);
        }

        fn step(&mut self) -> ScheduledStep {
            self.sched.schedule(&mut self.reqs, &mut self.kv, &mut self.residency, 0.0)
        }

        /// Simulate the engine applying execution results: advance
        /// computed counts, commit full blocks, append a token where
        /// produced (mirrors Engine::step's bookkeeping).
        fn apply(&mut self, step: &ScheduledStep) {
            for s in &step.seqs {
                let r = self.reqs.get_mut(&s.id).unwrap();
                r.num_computed_tokens = s.chunk_start + s.chunk_len;
                let full = r.num_computed_tokens / self.kv.block_size();
                let chain = r.hash_chain.prefix(full.min(r.hash_chain.len()));
                self.kv.commit_full_blocks(s.id.0, &chain);
                let r = self.reqs.get_mut(&s.id).unwrap();
                if s.produces_token {
                    r.output_tokens.push(7);
                    if r.output_tokens.len() as u32 >= r.params.max_new_tokens {
                        r.state = State::Finished;
                        self.sched.finish(s.id);
                        self.kv.free_request(s.id.0);
                    }
                }
            }
        }
    }

    #[test]
    fn single_request_prefill_then_decode() {
        let mut f = fixture(64, 8, 64);
        f.submit(mk_req(1, 100, 3));
        // step 1: 64-token chunk (budget-bound)
        let s1 = f.step();
        assert_eq!(s1.seqs.len(), 1);
        assert_eq!(s1.seqs[0].chunk_len, 64);
        assert!(!s1.seqs[0].produces_token);
        f.apply(&s1);
        // step 2: remaining 36 -> produces first token
        let s2 = f.step();
        assert_eq!(s2.seqs[0].chunk_len, 36);
        assert!(s2.seqs[0].produces_token);
        f.apply(&s2);
        // step 3: decode (1 token)
        let s3 = f.step();
        assert_eq!(s3.seqs[0].chunk_len, 1);
        assert!(s3.seqs[0].is_decode);
        assert!(s3.seqs[0].produces_token);
        f.apply(&s3);
        let s4 = f.step();
        f.apply(&s4);
        assert!(f.reqs[&RequestId(1)].is_finished());
        assert!(!f.sched.has_work());
    }

    #[test]
    fn chunked_prefill_interleaves_with_decode() {
        let mut f = fixture(32, 8, 128);
        f.submit(mk_req(1, 16, 8));
        let s = f.step();
        f.apply(&s); // req 1 prefilled, produced token -> decoding
        f.submit(mk_req(2, 200, 4));
        let s = f.step();
        // decode of req1 (1 token) + chunk of req2 (31 tokens)
        assert_eq!(s.seqs.len(), 2);
        let d = s.seqs.iter().find(|x| x.id == RequestId(1)).unwrap();
        assert!(d.is_decode && d.chunk_len == 1);
        let p = s.seqs.iter().find(|x| x.id == RequestId(2)).unwrap();
        assert!(!p.is_decode && p.chunk_len == 31);
        assert_eq!(s.total_tokens, 32);
    }

    #[test]
    fn admission_respects_max_num_seqs() {
        let mut f = fixture(1024, 2, 128);
        for i in 0..4 {
            f.submit(mk_req(i, 16, 4));
        }
        let s = f.step();
        assert_eq!(s.admitted.len(), 2);
        assert_eq!(f.sched.num_waiting(), 2);
    }

    #[test]
    fn prefix_cache_hit_skips_prefill() {
        let mut f = fixture(256, 8, 128);
        f.submit(mk_req(1, 64, 1));
        let s = f.step();
        f.apply(&s);
        assert!(f.reqs[&RequestId(1)].is_finished());
        // identical prompt: 3 of 4 blocks usable (cap at len-1), so the
        // chunk is 64 - 48 = 16 tokens.
        f.submit(mk_req(2, 64, 1));
        let s2 = f.step();
        assert_eq!(s2.seqs[0].chunk_start, 48);
        assert_eq!(s2.seqs[0].chunk_len, 16);
        let r2 = &f.reqs[&RequestId(2)];
        assert_eq!(r2.num_cached_tokens, 48);
    }

    #[test]
    fn full_cache_hit_still_computes_one_block() {
        let mut f = fixture(256, 8, 128);
        // 64-token prompt + generation; second request has the same 64
        // tokens AND the chain fully covers it.
        f.submit(mk_req(1, 64, 1));
        let s = f.step();
        f.apply(&s);
        f.submit(mk_req(2, 64, 2));
        let s2 = f.step();
        // usable capped at (64+2-1)/16*16 = 64? no wait: total_len at
        // admission = 64 (no outputs yet) -> cap (64-1)/16 = 3 blocks = 48.
        assert!(s2.seqs[0].chunk_len >= 1);
        assert!(s2.seqs[0].chunk_start <= 63);
    }

    #[test]
    fn preemption_under_block_pressure() {
        // Pool of 8 blocks = 128 tokens. Two requests of 96 tokens each
        // can't both hold capacity to completion.
        let mut f = fixture(1024, 8, 8);
        f.submit(mk_req(1, 90, 30)); // 120 tokens = 8 blocks (fits alone)
        f.submit(mk_req(2, 90, 30));
        let s1 = f.step();
        // both admitted (90+90=180 tokens > 128 capacity? 6 blocks each =
        // 12 > 8, so the second admission must have failed or preempted)
        assert_eq!(s1.admitted.len(), 1, "only one fits");
        f.apply(&s1);
        // run 1 to completion while 2 waits
        for _ in 0..60 {
            let s = f.step();
            if s.is_empty() {
                break;
            }
            f.apply(&s);
            if f.reqs[&RequestId(1)].is_finished() {
                break;
            }
        }
        assert!(f.reqs[&RequestId(1)].is_finished());
        // now 2 gets in
        let s = f.step();
        assert!(s.seqs.iter().any(|x| x.id == RequestId(2)));
    }

    #[test]
    fn decode_time_preemption_recomputes() {
        // One long-running decode + one new long prompt exhaust blocks;
        // the newest running request gets preempted and later recovers.
        let mut f = fixture(1024, 8, 8); // 128 tokens capacity
        f.submit(mk_req(1, 60, 40)); // grows to 100 tokens (7 blocks)
        let s = f.step();
        f.apply(&s);
        f.submit(mk_req(2, 60, 40)); // 7 + 7 blocks > 8 -> pressure
        let s = f.step();
        f.apply(&s);
        let mut preempted = 0;
        for _ in 0..400 {
            let s = f.step();
            preempted += s.preempted.len();
            if s.is_empty() && !f.sched.has_work() {
                break;
            }
            f.apply(&s);
        }
        assert!(preempted > 0, "expected preemption under pressure");
        assert!(f.reqs[&RequestId(1)].is_finished());
        assert!(f.reqs[&RequestId(2)].is_finished());
        assert!(f.reqs.values().any(|r| r.preemptions > 0));
        f.kv.check_invariants().unwrap();
        assert_eq!(f.kv.num_free_blocks(), 8, "all blocks returned");
    }

    #[test]
    fn self_preemption_when_lone_request_outgrows_pool() {
        // 4 blocks = 64 tokens of KV; a single request targeting 80 total
        // tokens hits `victim == id` in phase 1: growing its own table
        // fails, the preemption scan reaches itself, and the `continue
        // 'running` path must drop its packed chunk instead of scheduling
        // a request whose blocks were just released. (Engine::submit's
        // capacity check rejects such requests up front; the scheduler
        // still has to stay sane if one slips in.)
        let mut f = fixture(1024, 8, 4);
        f.submit(mk_req(1, 40, 40));
        let s = f.step();
        assert_eq!(s.seqs[0].chunk_len, 40, "prefill fits (3 blocks)");
        f.apply(&s);
        let mut preempt_step = None;
        for _ in 0..40 {
            let s = f.step();
            if !s.preempted.is_empty() {
                preempt_step = Some(s);
                break;
            }
            assert!(!s.is_empty(), "stalled before self-preemption");
            f.apply(&s);
        }
        let s = preempt_step.expect("never hit block pressure");
        assert_eq!(s.preempted, vec![RequestId(1)]);
        // The victim's own chunk was dropped, and phase-2 re-admission
        // rolled back (its cached prefix + remainder still needs 5 blocks):
        // the step must be empty rather than half-scheduled.
        assert!(s.seqs.is_empty(), "{:?}", s.seqs);
        assert!(s.admitted.is_empty());
        assert_eq!(s.total_tokens, 0);
        let r = &f.reqs[&RequestId(1)];
        assert_eq!(r.preemptions, 1);
        assert_eq!(r.state, State::Preempted);
        assert_eq!(f.sched.num_waiting(), 1);
        assert_eq!(f.sched.num_running(), 0);
        // All blocks returned (admission rollback freed its cache refs).
        assert_eq!(f.kv.num_free_blocks(), 4);
        f.kv.check_invariants().unwrap();
        // Every subsequent step is empty — the engine surfaces this as a
        // stall instead of spinning on preempt/re-admit forever.
        assert!(f.step().is_empty());
    }

    #[test]
    fn admission_watermark_boundary_and_drain() {
        // 8-block pool, watermark 0.75 → projected-use limit = 6 blocks.
        let watermark_fixture = || Fixture {
            sched: Scheduler::new(SchedulerConfig {
                max_batch_tokens: 1024,
                max_num_seqs: 8,
                max_seq_len: 4096,
                admission_watermark: 0.75,
            }),
            reqs: FxHashMap::default(),
            kv: KvCacheManager::new(8, 16, true),
            residency: AdapterResidency::disabled(),
        };

        // Empty running set: even an OVER-limit request is admitted (the
        // `!running.is_empty()` escape — deferring with nothing running
        // would deadlock the queue forever).
        let mut f = watermark_fixture();
        f.submit(mk_req(1, 90, 10)); // final 100 → demand 7 blocks > limit 6
        let s = f.step();
        assert_eq!(s.admitted, vec![RequestId(1)], "empty-running escape");
        f.apply(&s);
        for _ in 0..20 {
            let s = f.step();
            if s.is_empty() {
                break;
            }
            f.apply(&s);
        }
        assert!(f.reqs[&RequestId(1)].is_finished());

        // Boundary arithmetic on a fresh scheduler.
        let mut f = watermark_fixture();
        f.submit(mk_req(2, 30, 2)); // final 32 → demand 2 blocks
        let s1 = f.step();
        assert_eq!(s1.admitted, vec![RequestId(2)]);
        f.apply(&s1); // holds 2 blocks, decoding
        // Boundary case: in_use (2) + demand (4) == limit (6) → admitted
        // (the control defers only strictly-above-limit projections).
        f.submit(mk_req(3, 60, 4)); // final 64 → demand 4 blocks
        // One block over: in_use (6 after req3) + demand (1) > 6 → deferred
        // this time, because the running set is non-empty.
        f.submit(mk_req(4, 10, 2)); // final 12 → demand 1 block
        let s2 = f.step();
        assert_eq!(s2.admitted, vec![RequestId(3)], "boundary == limit admits");
        assert_eq!(f.sched.num_waiting(), 1, "over-limit request deferred");
        f.apply(&s2);
        // The deferral lifts once running work drains.
        for _ in 0..20 {
            let s = f.step();
            if s.is_empty() {
                break;
            }
            f.apply(&s);
            if f.reqs[&RequestId(4)].state == State::Running
                || f.reqs[&RequestId(4)].is_finished()
            {
                break;
            }
        }
        assert!(
            f.reqs[&RequestId(4)].state == State::Running
                || f.reqs[&RequestId(4)].is_finished(),
            "deferred request admitted after drain"
        );
        f.kv.check_invariants().unwrap();
    }

    #[test]
    fn same_step_preempted_batch_requeues_fcfs() {
        // Pool of 8 blocks. A and B (46-token prompts) each hold 3 blocks;
        // C and D (8-token prompts) hold 1 each — 8/8 used, 0 free. At
        // total 49 both A and B need their 4th block in the SAME step, so
        // two victims fall in one step: A preempts D (the newest), then B
        // preempts C. Preemption order is therefore [D, C] — reverse of
        // admission — but the waiting queue must come out in original
        // FCFS order [C, D], and later admission must follow it.
        let mut f = fixture(1024, 8, 8);
        f.submit(mk_req(1, 46, 4)); // A
        f.submit(mk_req(2, 46, 4)); // B
        f.submit(mk_req(3, 8, 40)); // C
        f.submit(mk_req(4, 8, 40)); // D
        let s = f.step();
        assert_eq!(s.admitted.len(), 4);
        f.apply(&s);
        // Two quiet decode steps (totals 47, 48 stay within 3 blocks).
        for _ in 0..2 {
            let s = f.step();
            assert!(s.preempted.is_empty());
            f.apply(&s);
        }
        // The pressure step: both A and B grow a block.
        let s = f.step();
        assert_eq!(
            s.preempted,
            vec![RequestId(4), RequestId(3)],
            "victims picked newest-first"
        );
        assert_eq!(
            f.sched.waiting.iter().copied().collect::<Vec<_>>(),
            vec![RequestId(3), RequestId(4)],
            "same-step victims re-queued in original FCFS order"
        );
        f.apply(&s); // A and B produce token 4 and finish, freeing blocks
        assert!(f.reqs[&RequestId(1)].is_finished());
        assert!(f.reqs[&RequestId(2)].is_finished());
        // Recovery admits the victims in FCFS order.
        let s = f.step();
        assert_eq!(s.admitted, vec![RequestId(3), RequestId(4)]);
        f.kv.check_invariants().unwrap();
    }

    #[test]
    fn budget_zero_admits_nothing() {
        let mut f = fixture(4, 8, 64);
        f.submit(mk_req(1, 100, 1));
        let s = f.step();
        assert_eq!(s.total_tokens, 4);
        // budget fully consumed by req1's chunk; nothing else happens
        f.submit(mk_req(2, 10, 1));
        let s = f.step();
        assert_eq!(s.seqs.len(), 1, "no budget left for admission");
        assert_eq!(s.seqs[0].id, RequestId(1));
    }

    #[test]
    fn property_scheduler_never_overcommits_budget_or_blocks() {
        use crate::util::prop;
        prop::check("sched-budget", 20, |rng, _| {
            let budget = rng.range(8, 128) as u32;
            let blocks = rng.range(8, 64) as u32;
            let mut f = fixture(budget, 8, blocks);
            let mut next_id = 0u64;
            for _ in 0..80 {
                if rng.next_below(3) == 0 {
                    let plen = rng.range(1, 200) as usize;
                    let gen = rng.range(1, 32) as u32;
                    f.submit(mk_req(next_id, plen, gen));
                    next_id += 1;
                }
                let s = f.step();
                if s.total_tokens > budget as usize {
                    return Err(format!(
                        "step packed {} tokens > budget {budget}",
                        s.total_tokens
                    ));
                }
                f.apply(&s);
                f.kv.check_invariants().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }
}
