//! Declarative JSON stage-graph specs — the wire format of the server's
//! `POST /pipeline` endpoint (DESIGN.md §6.3).
//!
//! A spec is an object with a `stages` array; stages reference earlier
//! stages *by name*:
//!
//! ```json
//! {"stages": [
//!   {"name": "draft", "gen": 64, "prompt": [[1,2,3,4]]},
//!   {"name": "check", "adapter": "alora-0", "gen": 16, "invoke": true,
//!    "prompt": [{"prompt_of": "draft"}, {"output_of": "draft"}]},
//!   {"name": "final", "gen": 32,
//!    "prompt": [{"prompt_of": "draft"}, {"output_of": "draft"},
//!               {"output_of": "check"}]}
//! ]}
//! ```
//!
//! Prompt parts: a bare array of token ids (literal), `{"tokens": [...]}`
//! (same), `{"prompt_of": name}`, `{"output_of": name}`. Optional stage
//! fields: `adapter` (registry name or index; absent/null = base model),
//! `gen`/`max_new_tokens` (default 16), `invoke` (append the adapter's
//! registered invocation tokens), `after` (ordering-only deps by name),
//! `priority` (queue-priority continuation).

use crate::adapter::{AdapterId, AdapterRegistry};
use crate::request::ModelTarget;
use crate::util::json::Json;

use super::{CoordinatorResult, Part, StageGraph, StageId, StageOutput, StageSpec};

fn lookup(ids: &[(String, StageId)], name: &str) -> anyhow::Result<StageId> {
    ids.iter()
        .find(|(n, _)| n == name)
        .map(|(_, id)| *id)
        .ok_or_else(|| anyhow::anyhow!("stage `{name}` referenced before definition"))
}

/// Parse a JSON stage-graph spec against an adapter registry.
pub fn graph_from_json(j: &Json, registry: &AdapterRegistry) -> anyhow::Result<StageGraph> {
    let stages = j
        .get("stages")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("spec must have a `stages` array"))?;
    anyhow::ensure!(!stages.is_empty(), "`stages` is empty");
    let mut graph = StageGraph::new();
    let mut ids: Vec<(String, StageId)> = Vec::new();
    for (idx, sj) in stages.iter().enumerate() {
        let name = sj
            .get("name")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("stage{idx}"));
        anyhow::ensure!(
            ids.iter().all(|(n, _)| n != &name),
            "duplicate stage name `{name}`"
        );
        let target = match sj.get("adapter") {
            None | Some(Json::Null) => ModelTarget::Base,
            Some(v) => {
                let adapter = if let Some(s) = v.as_str() {
                    registry
                        .by_name(s)
                        .ok_or_else(|| anyhow::anyhow!("unknown adapter `{s}`"))?
                } else if let Some(i) = v.as_u64() {
                    registry
                        .get(AdapterId(i as u32))
                        .ok_or_else(|| anyhow::anyhow!("unknown adapter index {i}"))?
                } else {
                    anyhow::bail!("stage `{name}`: `adapter` must be a name or index")
                };
                ModelTarget::Adapter(adapter.id)
            }
        };
        let gen_len = sj
            .get("gen")
            .or_else(|| sj.get("max_new_tokens"))
            .and_then(Json::as_u64)
            .unwrap_or(16) as u32;
        let mut parts = Vec::new();
        if let Some(pj) = sj.get("prompt") {
            let arr = pj
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("stage `{name}`: `prompt` must be an array of parts"))?;
            for p in arr {
                if let Some(tokens) = p.u32_vec() {
                    parts.push(Part::Tokens(tokens));
                } else if let Some(r) = p.get("prompt_of").and_then(Json::as_str) {
                    parts.push(Part::PromptOf(lookup(&ids, r)?));
                } else if let Some(r) = p.get("output_of").and_then(Json::as_str) {
                    parts.push(Part::OutputOf(lookup(&ids, r)?));
                } else if let Some(tokens) = p.get("tokens").and_then(Json::u32_vec) {
                    parts.push(Part::Tokens(tokens));
                } else {
                    anyhow::bail!("stage `{name}`: unrecognized prompt part {p}");
                }
            }
        }
        if sj.get("invoke").and_then(Json::as_bool).unwrap_or(false) {
            let ModelTarget::Adapter(aid) = target else {
                anyhow::bail!("stage `{name}`: `invoke` requires an adapter target");
            };
            let inv = registry
                .get(aid)
                .and_then(|a| a.invocation_tokens())
                .ok_or_else(|| {
                    anyhow::anyhow!("stage `{name}`: adapter has no invocation tokens")
                })?;
            parts.push(Part::Tokens(inv.to_vec()));
        }
        let mut after = Vec::new();
        if let Some(aj) = sj.get("after") {
            let arr = aj
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("stage `{name}`: `after` must be an array"))?;
            for a in arr {
                let pname = a
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("stage `{name}`: `after` entries must be stage names"))?;
                after.push(lookup(&ids, pname)?);
            }
        }
        let priority = sj.get("priority").and_then(Json::as_bool).unwrap_or(false);
        let id = graph
            .add(StageSpec { name: name.clone(), target, gen_len, parts, after, priority })
            .map_err(|e| anyhow::anyhow!("stage `{name}`: {e}"))?;
        ids.push((name, id));
    }
    Ok(graph)
}

/// Render one finished stage as a `POST /pipeline` response entry.
pub fn stage_output_to_json(o: &StageOutput) -> Json {
    let out = &o.output;
    Json::obj(vec![
        ("name", Json::str(o.name.clone())),
        ("conversation", Json::num(o.conversation as f64)),
        (
            "tokens",
            Json::Arr(
                out.output_tokens
                    .iter()
                    .map(|&t| Json::num(t as f64))
                    .collect(),
            ),
        ),
        ("prompt_len", Json::num(out.prompt_len as f64)),
        ("e2e_s", Json::num(out.timeline.e2e())),
        ("ttft_s", Json::num(out.timeline.ttft())),
        ("queue_s", Json::num(out.timeline.queue_time())),
        ("prefill_s", Json::num(out.timeline.prefill_time())),
        ("decode_s", Json::num(out.timeline.decode_time())),
        ("cache_hit_rate", Json::num(out.cache_hit_rate())),
    ])
}

/// Render a coordinator run as the `POST /pipeline` response body.
pub fn result_to_json(r: &CoordinatorResult) -> Json {
    Json::obj(vec![
        ("makespan_s", Json::num(r.makespan)),
        (
            "stages",
            Json::Arr(r.outputs.iter().map(stage_output_to_json).collect()),
        ),
    ])
}

/// Render a batched run: one entry per input spec, in input order — its
/// completion-ordered stages, or the error that kept it out of (or threw
/// it out of) the run. `convs[i]` maps input `i` to its conversation
/// index. One pass over the outputs: stages group by conversation first,
/// so rendering stays O(stages + pipelines) rather than rescanning the
/// outputs per entry.
pub fn batch_result_to_json(r: &CoordinatorResult, convs: &[Result<usize, String>]) -> Json {
    let mut by_conv: std::collections::BTreeMap<usize, Vec<Json>> =
        std::collections::BTreeMap::new();
    for o in &r.outputs {
        by_conv.entry(o.conversation).or_default().push(stage_output_to_json(o));
    }
    let pipelines: Vec<Json> = convs
        .iter()
        .map(|c| match c {
            Err(e) => Json::obj(vec![("error", Json::str(e.clone()))]),
            Ok(ci) => Json::obj(vec![(
                "stages",
                Json::Arr(by_conv.remove(ci).unwrap_or_default()),
            )]),
        })
        .collect();
    Json::obj(vec![
        ("makespan_s", Json::num(r.makespan)),
        ("pipelines", Json::Arr(pipelines)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::workload;

    fn registry() -> AdapterRegistry {
        workload::build_registry(2, 512, true)
    }

    #[test]
    fn parses_chain_with_invocation() {
        let j = Json::parse(
            r#"{"stages": [
                {"name": "draft", "gen": 8, "prompt": [[1,2,3,4]]},
                {"name": "check", "adapter": "alora-0", "gen": 4, "invoke": true,
                 "prompt": [{"prompt_of": "draft"}, {"output_of": "draft"}],
                 "priority": true}
            ]}"#,
        )
        .unwrap();
        let g = graph_from_json(&j, &registry()).unwrap();
        assert_eq!(g.len(), 2);
        assert_eq!(g.stage(StageId(0)).name, "draft");
        assert_eq!(g.level(StageId(1)), 1);
        assert!(g.stage(StageId(1)).priority);
        // invoke appended the adapter-0 invocation tokens as a literal part
        let last = g.stage(StageId(1)).parts.last().unwrap();
        assert_eq!(last, &Part::Tokens(workload::invocation_for(512, 0)));
    }

    #[test]
    fn adapter_by_index_and_after_edges() {
        let j = Json::parse(
            r#"{"stages": [
                {"name": "a", "gen": 4, "prompt": [[7,8,9]]},
                {"name": "b", "adapter": 1, "gen": 4,
                 "prompt": [[1]], "after": ["a"]}
            ]}"#,
        )
        .unwrap();
        let g = graph_from_json(&j, &registry()).unwrap();
        assert_eq!(g.parents(StageId(1)), &[StageId(0)]);
        match g.stage(StageId(1)).target {
            ModelTarget::Adapter(id) => assert_eq!(id.0, 1),
            t => panic!("wrong target {t:?}"),
        }
    }

    #[test]
    fn rejects_bad_specs() {
        let reg = registry();
        for bad in [
            r#"{"no_stages": true}"#,
            r#"{"stages": []}"#,
            r#"{"stages": [{"name": "x", "prompt": [[1]]},
                           {"name": "x", "prompt": [[2]]}]}"#,
            r#"{"stages": [{"name": "a", "prompt": [{"output_of": "ghost"}]}]}"#,
            r#"{"stages": [{"name": "a", "adapter": "nope", "prompt": [[1]]}]}"#,
            r#"{"stages": [{"name": "a", "prompt": [[1]], "invoke": true}]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(graph_from_json(&j, &reg).is_err(), "accepted: {bad}");
        }
    }

    fn one_stage_result(conversation: usize) -> StageOutput {
        use crate::request::{RequestId, RequestOutput, Timeline};
        let mut t = Timeline::new(0.0);
        t.first_scheduled = 0.1;
        t.first_token = 0.2;
        t.finished = 0.5;
        StageOutput {
            conversation,
            stage: StageId(0),
            name: "draft".into(),
            target: ModelTarget::Base,
            output: RequestOutput {
                id: RequestId(conversation as u64),
                target: ModelTarget::Base,
                prompt_len: 4,
                output_tokens: vec![1, 2],
                timeline: t,
                num_cached_tokens: 2,
                preemptions: 0,
            },
        }
    }

    #[test]
    fn result_renders_per_stage_fields() {
        let r = CoordinatorResult { outputs: vec![one_stage_result(0)], makespan: 0.5 };
        let j = result_to_json(&r);
        let stages = j.get("stages").and_then(Json::as_arr).unwrap();
        assert_eq!(stages.len(), 1);
        assert_eq!(stages[0].get("name").and_then(Json::as_str), Some("draft"));
        assert_eq!(stages[0].get("cache_hit_rate").and_then(Json::as_f64), Some(0.5));
    }

    #[test]
    fn batch_result_groups_by_input_and_keeps_errors_in_place() {
        // Inputs 0 and 2 parsed (conversations 0 and 1); input 1 failed.
        let r = CoordinatorResult {
            outputs: vec![one_stage_result(1), one_stage_result(0)],
            makespan: 0.5,
        };
        let convs = vec![Ok(0), Err("bad spec".to_string()), Ok(1)];
        let j = batch_result_to_json(&r, &convs);
        let ps = j.get("pipelines").and_then(Json::as_arr).unwrap();
        assert_eq!(ps.len(), 3);
        let s0 = ps[0].get("stages").and_then(Json::as_arr).unwrap();
        assert_eq!(s0.len(), 1);
        assert_eq!(s0[0].get("conversation").and_then(Json::as_u64), Some(0));
        assert_eq!(ps[1].get("error").and_then(Json::as_str), Some("bad spec"));
        let s2 = ps[2].get("stages").and_then(Json::as_arr).unwrap();
        assert_eq!(s2[0].get("conversation").and_then(Json::as_u64), Some(1));
    }
}
