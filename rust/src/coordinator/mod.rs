//! L3 orchestration: declarative stage graphs driven over any engine.
//!
//! The paper's headline wins come from *chained* requests — multi-turn,
//! multi-adapter pipelines whose follow-ups reuse prior-stage KV via
//! base-aligned hashing (§4.1, §4.4.1). This module generalizes the four
//! hard-coded `PipelineKind` shapes into an arbitrary DAG of stages:
//!
//! - [`StageGraph`] — nodes are {target (base or adapter), generation
//!   length, prompt-composition rule}; edges are dependencies. Prompts
//!   compose declaratively from [`Part`]s: literal tokens, a parent's
//!   composed prompt, or a parent's generated output — enough to express
//!   chains (base → eval), fan-out (one draft, N adapter "intrinsics" in
//!   the Activated-LoRA sense) and fan-in consolidation (one base call
//!   over every evaluation), at S-LoRA-style many-adapter scale.
//! - [`Coordinator`] — drives any [`EngineDriver`] (a single engine or a
//!   [`crate::cluster::Cluster`] of replicas) *event-style*: a stage is
//!   submitted the moment its last parent finishes, so the follow-up
//!   lands while the parent's prefix blocks are still cache-hot — and,
//!   over a cluster with prefix-affinity routing, lands on the replica
//!   that holds them, so child stages inherit their parent's placement.
//!   It tracks per-conversation frontier state and emits per-stage-name
//!   latency series into [`crate::metrics::Metrics::stage`].
//!
//! Two drive modes mirror the paper's methodologies: [`Coordinator::run_event`]
//! (§4.3 async — arrivals chain through the DAG as completions land) and
//! [`Coordinator::run_lockstep`] (§4.2 sync — every conversation advances
//! one topological level per wave). `pipeline::run_sync`/`run_poisson`
//! are now thin wrappers over these (DESIGN.md §6).

pub mod spec;

use crate::engine::EngineDriver;
use crate::metrics::StageLatencies;
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams};
use crate::util::fxmap::FxHashMap;

/// Index of a stage within one [`StageGraph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StageId(pub usize);

/// One piece of a stage's composed prompt.
#[derive(Debug, Clone, PartialEq)]
pub enum Part {
    /// Literal tokens (root prompts, invocation sequences, separators).
    Tokens(Vec<u32>),
    /// The referenced stage's *composed prompt* (its full input stream).
    PromptOf(StageId),
    /// The referenced stage's generated output tokens.
    OutputOf(StageId),
}

impl Part {
    fn stage_ref(&self) -> Option<StageId> {
        match self {
            Part::Tokens(_) => None,
            Part::PromptOf(s) | Part::OutputOf(s) => Some(*s),
        }
    }
}

/// One node of a stage graph.
#[derive(Debug, Clone)]
pub struct StageSpec {
    /// Label for metrics/traces (`metrics.stage` series key). Not required
    /// to be unique within a graph, but JSON specs and trace parent links
    /// resolve stages by name, so builders that feed those keep it unique.
    pub name: String,
    pub target: ModelTarget,
    /// Tokens to generate at this stage.
    pub gen_len: u32,
    /// Prompt composition, concatenated in order.
    pub parts: Vec<Part>,
    /// Extra ordering-only dependencies (no token flow).
    pub after: Vec<StageId>,
    /// Submit with queue priority (conversation continuations harvest
    /// their cached prefixes before eviction — paper §4.3 load
    /// management). Honored by the event drive; the lockstep drive
    /// ignores it, matching the fixed-batch methodology.
    pub priority: bool,
}

/// A DAG of stages for one conversation. Stages may only reference
/// earlier-added stages, so the graph is acyclic by construction.
#[derive(Debug, Clone, Default)]
pub struct StageGraph {
    stages: Vec<StageSpec>,
    /// Distinct parents per stage, in first-reference order.
    parents: Vec<Vec<StageId>>,
    /// Topological level per stage (roots = 0).
    levels: Vec<usize>,
}

impl StageGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.stages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    pub fn stage(&self, id: StageId) -> &StageSpec {
        &self.stages[id.0]
    }

    pub fn stages(&self) -> &[StageSpec] {
        &self.stages
    }

    pub fn parents(&self, id: StageId) -> &[StageId] {
        &self.parents[id.0]
    }

    pub fn level(&self, id: StageId) -> usize {
        self.levels[id.0]
    }

    pub fn max_level(&self) -> usize {
        self.levels.iter().copied().max().unwrap_or(0)
    }

    pub fn roots(&self) -> Vec<StageId> {
        (0..self.len())
            .map(StageId)
            .filter(|s| self.parents[s.0].is_empty())
            .collect()
    }

    /// Add a stage. Validates that every referenced stage exists (i.e. was
    /// added earlier — forward references are how cycles would sneak in),
    /// that it generates at least one token, and that roots carry a
    /// non-empty literal prompt.
    pub fn add(&mut self, spec: StageSpec) -> anyhow::Result<StageId> {
        let id = StageId(self.stages.len());
        anyhow::ensure!(spec.gen_len > 0, "stage `{}`: gen_len must be > 0", spec.name);
        let mut parents: Vec<StageId> = Vec::new();
        for r in spec
            .parts
            .iter()
            .filter_map(Part::stage_ref)
            .chain(spec.after.iter().copied())
        {
            anyhow::ensure!(
                r.0 < id.0,
                "stage `{}`: references stage #{} which is not defined yet \
                 (stages may only depend on earlier stages)",
                spec.name,
                r.0
            );
            if !parents.contains(&r) {
                parents.push(r);
            }
        }
        // Every stage must compose a non-empty prompt. PromptOf/OutputOf
        // parts are non-empty by induction (this same invariant on the
        // parent, and gen_len > 0), so at least one such part — or one
        // non-empty literal — suffices. This also covers non-root stages
        // with only `after` edges, whose composed prompt would otherwise
        // be empty and trip `Request::new`'s assertion at submit time.
        let can_be_nonempty = spec.parts.iter().any(|p| match p {
            Part::Tokens(t) => !t.is_empty(),
            Part::PromptOf(_) | Part::OutputOf(_) => true,
        });
        anyhow::ensure!(
            can_be_nonempty,
            "stage `{}` composes an empty prompt (needs a non-empty literal \
             or a parent part)",
            spec.name
        );
        let level = parents
            .iter()
            .map(|p| self.levels[p.0] + 1)
            .max()
            .unwrap_or(0);
        self.stages.push(spec);
        self.parents.push(parents);
        self.levels.push(level);
        Ok(id)
    }

    // -- builder conveniences (panic on invalid input: these construct
    //    well-formed shapes by design) ------------------------------------

    /// A root stage with a literal prompt.
    pub fn root(
        &mut self,
        name: &str,
        target: ModelTarget,
        prompt: Vec<u32>,
        gen_len: u32,
    ) -> StageId {
        self.add(StageSpec {
            name: name.to_string(),
            target,
            gen_len,
            parts: vec![Part::Tokens(prompt)],
            after: Vec::new(),
            priority: false,
        })
        .expect("invalid root stage")
    }

    /// Extend `parent`'s conversation: parent's prompt + parent's output +
    /// `suffix` (e.g. an adapter's invocation tokens).
    pub fn chain(
        &mut self,
        name: &str,
        target: ModelTarget,
        parent: StageId,
        suffix: Vec<u32>,
        gen_len: u32,
    ) -> StageId {
        let mut parts = vec![Part::PromptOf(parent), Part::OutputOf(parent)];
        if !suffix.is_empty() {
            parts.push(Part::Tokens(suffix));
        }
        self.add(StageSpec {
            name: name.to_string(),
            target,
            gen_len,
            parts,
            after: Vec::new(),
            priority: false,
        })
        .expect("invalid chain stage")
    }

    /// Fan-in: extend `primary`'s conversation with the outputs of every
    /// stage in `others` (paper §4.4.1's consolidated base call), plus an
    /// optional literal suffix.
    pub fn consolidate(
        &mut self,
        name: &str,
        target: ModelTarget,
        primary: StageId,
        others: &[StageId],
        suffix: Vec<u32>,
        gen_len: u32,
    ) -> StageId {
        let mut parts = vec![Part::PromptOf(primary), Part::OutputOf(primary)];
        parts.extend(others.iter().map(|&s| Part::OutputOf(s)));
        if !suffix.is_empty() {
            parts.push(Part::Tokens(suffix));
        }
        self.add(StageSpec {
            name: name.to_string(),
            target,
            gen_len,
            parts,
            after: Vec::new(),
            priority: false,
        })
        .expect("invalid consolidate stage")
    }

    /// Flip the priority flag of a stage (builder convenience).
    pub fn set_priority(&mut self, id: StageId, priority: bool) {
        self.stages[id.0].priority = priority;
    }
}

/// Per-conversation runtime state: the frontier the coordinator tracks.
#[derive(Debug)]
struct Conv {
    graph: StageGraph,
    /// Composed prompt per stage, retained at submission only for stages
    /// some child references via `Part::PromptOf` (long multi-conversation
    /// runs would otherwise hold every stage's full token stream twice).
    prompts: Vec<Option<Vec<u32>>>,
    /// Whether any child needs this stage's composed prompt retained.
    prompt_needed: Vec<bool>,
    /// Finished output per stage, retained only for stages some child
    /// references via `Part::OutputOf` (the completion stream in
    /// `Coordinator::finished` keeps the canonical copy).
    outputs: Vec<Option<RequestOutput>>,
    /// Whether any child needs this stage's output retained.
    output_needed: Vec<bool>,
    submitted: Vec<bool>,
    /// Finished flag per stage (outputs[] alone can't tell: un-referenced
    /// stages don't retain their output).
    done: Vec<bool>,
    /// Countdown of unfinished distinct parents per stage.
    pending_parents: Vec<usize>,
    /// Reverse edges, in stage-add order.
    children: Vec<Vec<StageId>>,
    remaining: usize,
}

/// One finished stage, in completion order.
#[derive(Debug, Clone)]
pub struct StageOutput {
    pub conversation: usize,
    pub stage: StageId,
    pub name: String,
    pub target: ModelTarget,
    pub output: RequestOutput,
}

/// All finished stages of a coordinator run.
#[derive(Debug, Clone, Default)]
pub struct CoordinatorResult {
    /// Completion-ordered stage outputs.
    pub outputs: Vec<StageOutput>,
    /// Engine virtual time when the run completed.
    pub makespan: f64,
}

impl CoordinatorResult {
    pub fn len(&self) -> usize {
        self.outputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.outputs.is_empty()
    }

    /// Latency series over the stages `want` selects.
    pub fn latencies(&self, want: impl Fn(&StageOutput) -> bool) -> StageLatencies {
        let mut s = StageLatencies::default();
        for o in &self.outputs {
            if want(o) {
                s.observe(&o.output);
            }
        }
        s
    }

    /// Latency series of every stage with this name (across conversations).
    pub fn latencies_of(&self, name: &str) -> StageLatencies {
        self.latencies(|o| o.name == name)
    }

    /// Mean prefix-cache hit rate over the stages `want` selects.
    pub fn hit_rate(&self, want: impl Fn(&StageOutput) -> bool) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for o in &self.outputs {
            if want(o) {
                sum += o.output.cache_hit_rate();
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    pub fn hit_rate_of(&self, name: &str) -> f64 {
        self.hit_rate(|o| o.name == name)
    }

    /// Distinct stage names in first-completion order.
    pub fn stage_names(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for o in &self.outputs {
            if !names.contains(&o.name) {
                names.push(o.name.clone());
            }
        }
        names
    }
}

/// Drives stage graphs over an engine. See the module docs for the two
/// drive modes; the low-level API ([`Coordinator::submit_ready`],
/// [`Coordinator::on_finished`], [`Coordinator::pump`]) lets external
/// drivers — e.g. the HTTP server's handler threads — share an engine with
/// other traffic while the coordinator chains their conversations.
pub struct Coordinator {
    convs: Vec<Conv>,
    /// In-flight request -> (conversation, stage).
    owner: FxHashMap<RequestId, (usize, StageId)>,
    /// Completion-ordered finished stages.
    finished: Vec<StageOutput>,
    remaining_total: usize,
    /// Whether submissions honor per-stage priority (event mode: yes;
    /// lockstep mode: no, matching the paper's fixed-batch §4.2 runs).
    honor_priority: bool,
    /// Rotating start for [`Coordinator::pump`]'s within-level round-robin
    /// across conversations (fairness across DAG depths).
    fair_cursor: usize,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    pub fn new() -> Self {
        Coordinator {
            convs: Vec::new(),
            owner: FxHashMap::default(),
            finished: Vec::new(),
            remaining_total: 0,
            honor_priority: true,
            fair_cursor: 0,
        }
    }

    /// Register a conversation; returns its index. Nothing is submitted
    /// until [`Coordinator::submit_ready`] is called for it.
    pub fn add_conversation(&mut self, graph: StageGraph) -> anyhow::Result<usize> {
        anyhow::ensure!(!graph.is_empty(), "empty stage graph");
        let n = graph.len();
        let mut children: Vec<Vec<StageId>> = vec![Vec::new(); n];
        let mut pending = vec![0usize; n];
        let mut prompt_needed = vec![false; n];
        let mut output_needed = vec![false; n];
        for i in 0..n {
            let ps = graph.parents(StageId(i)).to_vec();
            pending[i] = ps.len();
            for p in ps {
                children[p.0].push(StageId(i));
            }
            for part in &graph.stages[i].parts {
                match part {
                    Part::PromptOf(r) => prompt_needed[r.0] = true,
                    Part::OutputOf(r) => output_needed[r.0] = true,
                    Part::Tokens(_) => {}
                }
            }
        }
        self.convs.push(Conv {
            prompts: vec![None; n],
            prompt_needed,
            outputs: vec![None; n],
            output_needed,
            submitted: vec![false; n],
            done: vec![false; n],
            pending_parents: pending,
            children,
            remaining: n,
            graph,
        });
        self.remaining_total += n;
        Ok(self.convs.len() - 1)
    }

    pub fn conversation_count(&self) -> usize {
        self.convs.len()
    }

    pub fn graph(&self, conversation: usize) -> &StageGraph {
        &self.convs[conversation].graph
    }

    /// All stages retired so far across conversations.
    pub fn finished_stages(&self) -> &[StageOutput] {
        &self.finished
    }

    /// Stages retired since a cursor (completion-ordered) — the streaming
    /// server's per-stage emission intake: it remembers how many stages
    /// it has emitted and drains only the new ones each wake-up.
    pub fn finished_since(&self, cursor: usize) -> &[StageOutput] {
        &self.finished[cursor.min(self.finished.len())..]
    }

    pub fn is_done(&self) -> bool {
        self.remaining_total == 0
    }

    /// Stages currently submitted but not finished.
    pub fn in_flight(&self) -> usize {
        self.owner.len()
    }

    /// Does the coordinator own this in-flight request?
    pub fn owns(&self, id: RequestId) -> bool {
        self.owner.contains_key(&id)
    }

    /// The conversation owning an in-flight request (None once retired or
    /// never owned) — lets callers attribute a completion-time failure to
    /// its conversation before [`Coordinator::on_finished`] consumes it.
    pub fn conversation_of(&self, id: RequestId) -> Option<usize> {
        self.owner.get(&id).map(|(ci, _)| *ci)
    }

    /// The stage name behind an in-flight request (None once retired or
    /// never owned) — lets a streaming server label per-token events with
    /// the stage they belong to while the stage is still generating.
    pub fn stage_name_of(&self, id: RequestId) -> Option<&str> {
        self.owner
            .get(&id)
            .map(|(ci, sid)| self.convs[*ci].graph.stage(*sid).name.as_str())
    }

    /// The request ids of every submitted-but-unfinished stage (for
    /// external drivers that must hand leftovers back on abort).
    pub fn in_flight_ids(&self) -> Vec<RequestId> {
        self.owner.keys().copied().collect()
    }

    /// The frontier of one conversation: submitted-but-unfinished stages.
    pub fn frontier(&self, conversation: usize) -> Vec<StageId> {
        let conv = &self.convs[conversation];
        (0..conv.graph.len())
            .map(StageId)
            .filter(|s| conv.submitted[s.0] && !conv.done[s.0])
            .collect()
    }

    /// Compose a stage's prompt from its parts. Parents must have been
    /// submitted (`PromptOf`) / finished (`OutputOf`) already.
    fn compose(conv: &Conv, id: StageId) -> Vec<u32> {
        let spec = &conv.graph.stages[id.0];
        let mut p = Vec::new();
        for part in &spec.parts {
            match part {
                Part::Tokens(t) => p.extend_from_slice(t),
                Part::PromptOf(s) => p.extend_from_slice(
                    conv.prompts[s.0].as_ref().expect("parent prompt not composed"),
                ),
                Part::OutputOf(s) => p.extend_from_slice(
                    &conv.outputs[s.0].as_ref().expect("parent not finished").output_tokens,
                ),
            }
        }
        p
    }

    /// Submit one stage (parents must be done). The composed prompt is
    /// retained for children's `PromptOf` parts.
    fn submit_stage<D: EngineDriver>(
        &mut self,
        engine: &mut D,
        ci: usize,
        sid: StageId,
    ) -> anyhow::Result<RequestId> {
        let prompt = Self::compose(&self.convs[ci], sid);
        let (target, gen_len, priority) = {
            let s = &self.convs[ci].graph.stages[sid.0];
            // Backstop for the graph-level invariant: an Err here reaches
            // callers (e.g. a 400 from POST /pipeline), a panic inside
            // `Engine::submit` would poison the server's engine mutex.
            anyhow::ensure!(
                !prompt.is_empty(),
                "stage `{}` composed an empty prompt",
                s.name
            );
            (s.target, s.gen_len, s.priority)
        };
        if self.convs[ci].prompt_needed[sid.0] {
            self.convs[ci].prompts[sid.0] = Some(prompt.clone());
        }
        let id = engine.submit_with_priority(
            target,
            prompt,
            SamplingParams { max_new_tokens: gen_len, ..Default::default() },
            self.honor_priority && priority,
        )?;
        self.convs[ci].submitted[sid.0] = true;
        self.owner.insert(id, (ci, sid));
        Ok(id)
    }

    /// Submit every ready stage of a conversation (all parents finished,
    /// not yet submitted). For a fresh conversation this starts its roots.
    /// Returns the number of stages submitted.
    pub fn submit_ready<D: EngineDriver>(
        &mut self,
        engine: &mut D,
        conversation: usize,
    ) -> anyhow::Result<usize> {
        let ready: Vec<StageId> = {
            let conv = &self.convs[conversation];
            (0..conv.graph.len())
                .map(StageId)
                .filter(|s| !conv.submitted[s.0] && conv.pending_parents[s.0] == 0)
                .collect()
        };
        for &s in &ready {
            self.submit_stage(engine, conversation, s)?;
        }
        Ok(ready.len())
    }

    /// Record a finished stage: store its output, update the frontier and
    /// the per-stage-name metrics series.
    fn retire<D: EngineDriver>(
        &mut self,
        engine: &mut D,
        out: RequestOutput,
    ) -> anyhow::Result<(usize, StageId)> {
        let (ci, sid) = self
            .owner
            .remove(&out.id)
            .ok_or_else(|| anyhow::anyhow!("request {:?} is not coordinator-owned", out.id))?;
        let (name, target) = {
            let s = &self.convs[ci].graph.stages[sid.0];
            (s.name.clone(), s.target)
        };
        engine.metrics_mut().observe_stage(&name, &out);
        let children = self.convs[ci].children[sid.0].clone();
        for c in children {
            self.convs[ci].pending_parents[c.0] -= 1;
        }
        if self.convs[ci].output_needed[sid.0] {
            self.convs[ci].outputs[sid.0] = Some(out.clone());
        }
        self.convs[ci].done[sid.0] = true;
        self.convs[ci].remaining -= 1;
        self.remaining_total -= 1;
        self.finished.push(StageOutput {
            conversation: ci,
            stage: sid,
            name,
            target,
            output: out,
        });
        Ok((ci, sid))
    }

    /// Event-style completion intake: retire the stage and immediately
    /// submit any children it unblocked — the chained request lands while
    /// the parent's prefix blocks are still cache-hot.
    pub fn on_finished<D: EngineDriver>(
        &mut self,
        engine: &mut D,
        out: RequestOutput,
    ) -> anyhow::Result<()> {
        let (ci, sid) = self.retire(engine, out)?;
        let ready: Vec<StageId> = {
            let conv = &self.convs[ci];
            conv.children[sid.0]
                .iter()
                .copied()
                .filter(|c| conv.pending_parents[c.0] == 0 && !conv.submitted[c.0])
                .collect()
        };
        for c in ready {
            self.submit_stage(engine, ci, c)?;
        }
        Ok(())
    }

    /// Abandon a conversation: its unfinished stages stop blocking
    /// [`Coordinator::is_done`], nothing further is submitted for it, and
    /// the request ids of its in-flight stages are returned so the caller
    /// can discard their eventual outputs (the engine keeps running them;
    /// the coordinator just stops listening). Used by the server's batch
    /// `POST /pipeline` to isolate one graph's runtime submission failure
    /// from the rest of the batch.
    pub fn abandon_conversation(&mut self, conversation: usize) -> Vec<RequestId> {
        let in_flight: Vec<RequestId> = self
            .owner
            .iter()
            .filter(|(_, (ci, _))| *ci == conversation)
            .map(|(id, _)| *id)
            .collect();
        for id in &in_flight {
            self.owner.remove(id);
        }
        let conv = &mut self.convs[conversation];
        self.remaining_total -= conv.remaining;
        conv.remaining = 0;
        // Mark everything submitted+done so no frontier scan or
        // submit_ready call can resurrect the conversation.
        for i in 0..conv.graph.len() {
            conv.submitted[i] = true;
            conv.done[i] = true;
        }
        in_flight
    }

    /// Drain the engine's finished queue for coordinator-owned requests
    /// (leaving other traffic's outputs in place) and chain follow-ups.
    ///
    /// Fairness across DAG depths: every drained stage is retired FIRST,
    /// and only then are the unlocked children submitted — ordered
    /// shallowest graph level first, round-robin across conversations
    /// within a level (rotating start). Submitting per-completion instead
    /// (the old behavior, still what [`Coordinator::on_finished`] does for
    /// single completions) lets a deep chain whose stage happens to drain
    /// first enqueue its level-N follow-up ahead of conversations still
    /// near their roots, every single pump — FIFO admission then starves
    /// the shallow graphs under sustained load.
    ///
    /// Returns the number of stages retired.
    pub fn pump<D: EngineDriver>(&mut self, engine: &mut D) -> anyhow::Result<usize> {
        let outs = {
            let owner = &self.owner;
            engine.take_finished_where(|o| owner.contains_key(&o.id))
        };
        let n = outs.len();
        // Phase 1: retire everything drained, collecting unlocked
        // children. A child with several parents in this batch is pushed
        // exactly once — pending_parents only reaches 0 on the last one.
        let mut ready: Vec<(usize, StageId)> = Vec::new();
        for out in outs {
            let (ci, sid) = self.retire(engine, out)?;
            let conv = &self.convs[ci];
            for c in &conv.children[sid.0] {
                if conv.pending_parents[c.0] == 0 && !conv.submitted[c.0] {
                    ready.push((ci, *c));
                }
            }
        }
        // Phase 2: submit shallow-first, conversations rotating within a
        // level so equal-depth peers take turns going first.
        if ready.len() > 1 {
            let nc = self.convs.len();
            let start = self.fair_cursor % nc;
            ready.sort_by_key(|&(ci, sid)| {
                (self.convs[ci].graph.level(sid), (ci + nc - start) % nc, sid)
            });
            self.fair_cursor = self.fair_cursor.wrapping_add(1);
        }
        for (ci, sid) in ready {
            self.submit_stage(engine, ci, sid)?;
        }
        Ok(n)
    }

    /// Consume the coordinator into its result.
    pub fn into_result(self, makespan: f64) -> CoordinatorResult {
        CoordinatorResult { outputs: self.finished, makespan }
    }

    /// Event drive (paper §4.3 methodology): conversation `i` arrives at
    /// virtual time `arrivals[i]`; stages chain the moment their parents
    /// finish, honoring per-stage queue priority.
    pub fn run_event<D: EngineDriver>(
        engine: &mut D,
        graphs: Vec<StageGraph>,
        arrivals: &[f64],
    ) -> anyhow::Result<CoordinatorResult> {
        anyhow::ensure!(
            graphs.len() == arrivals.len(),
            "{} graphs but {} arrivals",
            graphs.len(),
            arrivals.len()
        );
        let mut co = Coordinator::new();
        co.honor_priority = true;
        for g in graphs {
            co.add_conversation(g)?;
        }
        let mut order: Vec<usize> = (0..arrivals.len()).collect();
        order.sort_by(|&a, &b| arrivals[a].partial_cmp(&arrivals[b]).expect("NaN arrival"));
        let mut next = 0usize;
        while !co.is_done() {
            while next < order.len() && arrivals[order[next]] <= engine.clock() {
                co.submit_ready(engine, order[next])?;
                next += 1;
            }
            let progressed = engine.step();
            co.pump(engine)?;
            if !progressed {
                if next < order.len() {
                    let t = arrivals[order[next]].max(engine.clock());
                    engine.advance_clock_to(t);
                } else if !co.is_done() && !engine.has_work() {
                    anyhow::bail!(
                        "coordinator stalled: {} stages unfinished, engine idle",
                        co.remaining_total
                    );
                }
            }
        }
        Ok(co.into_result(engine.clock()))
    }

    /// Lockstep drive (paper §4.2 methodology): every conversation
    /// advances one topological level per wave — all of level 0 submitted
    /// and run to completion, then all of level 1, and so on. Priority
    /// flags are ignored (the whole wave is one fixed batch).
    pub fn run_lockstep<D: EngineDriver>(
        engine: &mut D,
        graphs: Vec<StageGraph>,
    ) -> anyhow::Result<CoordinatorResult> {
        let mut co = Coordinator::new();
        co.honor_priority = false;
        for g in graphs {
            co.add_conversation(g)?;
        }
        let max_level = co.convs.iter().map(|c| c.graph.max_level()).max().unwrap_or(0);
        for level in 0..=max_level {
            let mut submitted_any = false;
            for ci in 0..co.convs.len() {
                let wave: Vec<StageId> = {
                    let conv = &co.convs[ci];
                    (0..conv.graph.len())
                        .map(StageId)
                        .filter(|s| conv.graph.level(*s) == level && !conv.submitted[s.0])
                        .collect()
                };
                for s in wave {
                    co.submit_stage(engine, ci, s)?;
                    submitted_any = true;
                }
            }
            if !submitted_any {
                continue;
            }
            engine.run_until_idle();
            let mut outs = {
                let owner = &co.owner;
                engine.take_finished_where(|o| owner.contains_key(&o.id))
            };
            // Record the wave in submission order (RequestIds are issued
            // monotonically), matching the legacy stage-locked drivers.
            outs.sort_by_key(|o| o.id);
            for out in outs {
                co.retire(engine, out)?;
            }
        }
        anyhow::ensure!(
            co.is_done(),
            "lockstep run left {} stages unfinished",
            co.remaining_total
        );
        Ok(co.into_result(engine.clock()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::AdapterId;
    use crate::config::presets;
    use crate::engine::Engine;
    use crate::pipeline::workload;
    use crate::simulator::SimExecutor;

    fn engine(n_adapters: u32) -> Engine<SimExecutor> {
        let cfg = presets::granite_8b();
        let reg = workload::build_registry(n_adapters, cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&cfg);
        Engine::with_registry(cfg, reg, exec)
    }

    fn fan_graph(prompt: Vec<u32>, vocab: u32, n_adapters: u32) -> StageGraph {
        let mut g = StageGraph::new();
        let draft = g.root("draft", ModelTarget::Base, prompt, 64);
        let evals: Vec<StageId> = (0..n_adapters)
            .map(|a| {
                g.chain(
                    &format!("eval-{a}"),
                    ModelTarget::Adapter(AdapterId(a)),
                    draft,
                    workload::invocation_for(vocab, a),
                    16,
                )
            })
            .collect();
        g.consolidate("consolidate", ModelTarget::Base, draft, &evals, Vec::new(), 16);
        g
    }

    #[test]
    fn graph_construction_and_levels() {
        let mut g = StageGraph::new();
        let a = g.root("a", ModelTarget::Base, vec![1, 2, 3], 4);
        let b = g.chain("b", ModelTarget::Adapter(AdapterId(0)), a, vec![9], 4);
        let c = g.chain("c", ModelTarget::Adapter(AdapterId(1)), a, vec![8], 4);
        let d = g.consolidate("d", ModelTarget::Base, a, &[b, c], Vec::new(), 4);
        assert_eq!(g.len(), 4);
        assert_eq!(g.level(a), 0);
        assert_eq!(g.level(b), 1);
        assert_eq!(g.level(c), 1);
        assert_eq!(g.level(d), 2);
        assert_eq!(g.max_level(), 2);
        assert_eq!(g.roots(), vec![a]);
        // `d` references `a` twice (PromptOf + OutputOf) but parents are
        // deduplicated.
        assert_eq!(g.parents(d), &[a, b, c]);
    }

    #[test]
    fn graph_rejects_invalid_stages() {
        let mut g = StageGraph::new();
        // forward reference
        assert!(g
            .add(StageSpec {
                name: "bad".into(),
                target: ModelTarget::Base,
                gen_len: 4,
                parts: vec![Part::OutputOf(StageId(3))],
                after: Vec::new(),
                priority: false,
            })
            .is_err());
        // empty root prompt
        assert!(g
            .add(StageSpec {
                name: "empty".into(),
                target: ModelTarget::Base,
                gen_len: 4,
                parts: vec![Part::Tokens(Vec::new())],
                after: Vec::new(),
                priority: false,
            })
            .is_err());
        // zero generation
        assert!(g
            .add(StageSpec {
                name: "zerogen".into(),
                target: ModelTarget::Base,
                gen_len: 0,
                parts: vec![Part::Tokens(vec![1])],
                after: Vec::new(),
                priority: false,
            })
            .is_err());
        assert!(g.is_empty());
    }

    #[test]
    fn event_drive_runs_fan_out_fan_in() {
        let mut e = engine(2);
        let vocab = e.cfg.model.vocab_size;
        let mut rng = crate::util::rng::Rng::new(3);
        let graphs: Vec<StageGraph> = (0..3)
            .map(|_| fan_graph(workload::prompt(&mut rng, 256, vocab), vocab, 2))
            .collect();
        let r = Coordinator::run_event(&mut e, graphs, &[0.0, 0.1, 0.2]).unwrap();
        assert_eq!(r.outputs.len(), 12); // 3 conversations × 4 stages
        assert_eq!(r.latencies_of("draft").count(), 3);
        assert_eq!(r.latencies_of("consolidate").count(), 3);
        // children never start before their parents finish
        for o in &r.outputs {
            if o.name == "consolidate" {
                let draft = r
                    .outputs
                    .iter()
                    .find(|p| p.conversation == o.conversation && p.name == "draft")
                    .unwrap();
                assert!(o.output.timeline.arrival >= draft.output.timeline.finished);
            }
        }
        // non-root stages reuse parent KV
        for name in ["eval-0", "eval-1", "consolidate"] {
            assert!(r.hit_rate_of(name) > 0.0, "{name} got no cache hits");
        }
        e.check_invariants().unwrap();
    }

    #[test]
    fn lockstep_and_event_complete_same_stages() {
        let vocab = presets::granite_8b().model.vocab_size;
        let build = || {
            let mut rng = crate::util::rng::Rng::new(9);
            (0..2)
                .map(|_| fan_graph(workload::prompt(&mut rng, 128, vocab), vocab, 2))
                .collect::<Vec<_>>()
        };
        let mut e1 = engine(2);
        let lock = Coordinator::run_lockstep(&mut e1, build()).unwrap();
        let mut e2 = engine(2);
        let event = Coordinator::run_event(&mut e2, build(), &[0.0, 0.0]).unwrap();
        assert_eq!(lock.outputs.len(), event.outputs.len());
        let mut a = lock.stage_names();
        let mut b = event.stage_names();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn per_stage_metrics_series_recorded() {
        let mut e = engine(1);
        let vocab = e.cfg.model.vocab_size;
        let mut g = StageGraph::new();
        let root = g.root("draft", ModelTarget::Base, vec![5; 128], 16);
        g.chain(
            "check",
            ModelTarget::Adapter(AdapterId(0)),
            root,
            workload::invocation_for(vocab, 0),
            8,
        );
        let r = Coordinator::run_event(&mut e, vec![g], &[0.0]).unwrap();
        assert_eq!(r.outputs.len(), 2);
        assert_eq!(e.metrics.stage.get("draft").map(|s| s.count()), Some(1));
        assert_eq!(e.metrics.stage.get("check").map(|s| s.count()), Some(1));
        let prom = e.metrics.render_prometheus();
        assert!(prom.contains("stage=\"draft\""), "{prom}");
    }

    #[test]
    fn abandoned_conversation_stops_blocking_is_done() {
        let mut e = engine(2);
        let vocab = e.cfg.model.vocab_size;
        let mut rng = crate::util::rng::Rng::new(4);
        let mut co = Coordinator::new();
        let keep = co
            .add_conversation(fan_graph(workload::prompt(&mut rng, 128, vocab), vocab, 2))
            .unwrap();
        let drop_ = co
            .add_conversation(fan_graph(workload::prompt(&mut rng, 128, vocab), vocab, 2))
            .unwrap();
        co.submit_ready(&mut e, keep).unwrap();
        co.submit_ready(&mut e, drop_).unwrap();
        let orphans = co.abandon_conversation(drop_);
        assert_eq!(orphans.len(), 1, "one in-flight root handed back");
        assert!(!co.owns(orphans[0]));
        assert!(co.frontier(drop_).is_empty());
        // Driving to completion now only waits on the kept conversation,
        // while the abandoned root's output stays in the engine queue for
        // the caller to discard.
        while !co.is_done() {
            assert!(e.step(), "stalled");
            co.pump(&mut e).unwrap();
        }
        let kept: Vec<_> = co.finished_stages().iter().map(|o| o.conversation).collect();
        assert!(kept.iter().all(|&c| c == keep));
        assert_eq!(kept.len(), 4);
        e.run_until_idle();
        let leftovers = e.take_finished();
        assert_eq!(leftovers.len(), 1, "abandoned root finished unclaimed");
        assert_eq!(leftovers[0].id, orphans[0]);
    }

    #[test]
    fn pump_submits_unlocked_stages_shallow_first_across_conversations() {
        let mut e = engine(1);
        let mut co = Coordinator::new();
        let chain_graph = |len: usize, seed: u32| {
            let mut g = StageGraph::new();
            let mut prev = g.root("s0", ModelTarget::Base, vec![seed; 64], 8);
            for i in 1..len {
                prev =
                    g.chain(&format!("s{i}"), ModelTarget::Base, prev, vec![seed + i as u32], 8);
            }
            g
        };
        let a = co.add_conversation(chain_graph(3, 1)).unwrap();
        let b = co.add_conversation(chain_graph(2, 1001)).unwrap();
        // Drive A one level ahead of B: a0 retires and a1 runs to
        // completion before B's root is even submitted.
        co.submit_ready(&mut e, a).unwrap();
        e.run_until_idle();
        co.pump(&mut e).unwrap(); // retires a0, submits a1
        e.run_until_idle(); // a1 finishes, sits in the queue
        co.submit_ready(&mut e, b).unwrap();
        e.run_until_idle(); // b0 finishes behind it
        // One pump now retires a1 and b0 together (a1 drained first),
        // unlocking a2 (level 2) and b1 (level 1). The fair pump submits
        // the shallower b1 first — the deep chain cannot keep enqueueing
        // its next level ahead of a conversation still near its root.
        // RequestIds are monotonic, so the order is directly observable.
        co.pump(&mut e).unwrap();
        let id_of = |co: &Coordinator, ci: usize| {
            co.owner
                .iter()
                .find(|(_, (c, _))| *c == ci)
                .map(|(id, _)| *id)
                .expect("stage in flight")
        };
        assert!(
            id_of(&co, b) < id_of(&co, a),
            "shallow stage must be submitted before the deep chain's next level"
        );
        e.run_until_idle();
        co.pump(&mut e).unwrap();
        assert!(co.is_done());
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut e = engine(2);
            let vocab = e.cfg.model.vocab_size;
            let mut rng = crate::util::rng::Rng::new(21);
            let graphs: Vec<StageGraph> = (0..4)
                .map(|_| fan_graph(workload::prompt(&mut rng, 200, vocab), vocab, 2))
                .collect();
            let r = Coordinator::run_event(&mut e, graphs, &[0.0, 0.5, 1.0, 1.5]).unwrap();
            (r.outputs.len(), r.makespan)
        };
        assert_eq!(run(), run());
    }
}
