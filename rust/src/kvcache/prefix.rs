//! Per-request hash chains with the base-aligned salting policy.
//!
//! [`HashContext`] captures how one request's blocks must be salted:
//!
//! | request kind              | vanilla vLLM      | base-aligned (ours)            |
//! |---------------------------|-------------------|--------------------------------|
//! | base model                | no salt           | no salt                        |
//! | standard LoRA             | salt on all blocks| salt on all blocks             |
//! | aLoRA, block < inv_start  | salt on all blocks| **no salt** (interchangeable)  |
//! | aLoRA, block ≥ inv_start  | salt on all blocks| salt                           |
//!
//! A block is "pre-activation" only if it ends at or before the activation
//! point — a block straddling the invocation start contains adapted tokens
//! and must be salted (Figure 3: the activation tokens are only cached once
//! they fill a block, and then under the adapter's salt).

use std::cell::Cell;

use super::block::BlockHash;
use super::hash::{block_hash, ExtraKeys};

thread_local! {
    /// Blocks hashed on this thread since the last [`take_hash_ops`] —
    /// the placement-cost probe the scale harness and the O(delta +
    /// replicas) acceptance test read. Thread-local (not atomic) so
    /// parallel tests can't race each other's counts.
    static HASH_OPS: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn count_hash_op() {
    HASH_OPS.with(|c| c.set(c.get() + 1));
}

/// Drain this thread's block-hash op counter (reads and resets).
pub fn take_hash_ops() -> u64 {
    HASH_OPS.with(|c| c.replace(0))
}

/// Salting policy inputs for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HashContext {
    /// Internal adapter ID (None = base model request).
    pub adapter_id: Option<u32>,
    /// True if the adapter is an Activated LoRA.
    pub is_alora: bool,
    /// Absolute token index where the activation sequence begins (aLoRA
    /// only; ignored otherwise).
    pub inv_start: usize,
    /// Engine feature flag (cache.base_aligned_hashing).
    pub base_aligned: bool,
    /// Multi-tenant cache salt (0 = none).
    pub cache_salt: u64,
}

impl HashContext {
    pub fn base() -> Self {
        HashContext {
            adapter_id: None,
            is_alora: false,
            inv_start: 0,
            base_aligned: true,
            cache_salt: 0,
        }
    }

    /// Which salt applies to a block spanning token indices
    /// [block_start, block_end)?
    #[inline]
    pub fn salt_for_block(&self, _block_start: usize, block_end: usize) -> Option<u32> {
        match self.adapter_id {
            None => None,
            Some(id) => {
                if self.is_alora && self.base_aligned && block_end <= self.inv_start {
                    // Entirely pre-activation: hash as the base model.
                    None
                } else {
                    Some(id)
                }
            }
        }
    }

    fn extra_for_block(&self, block_start: usize, block_end: usize) -> ExtraKeys {
        ExtraKeys {
            adapter_salt: self.salt_for_block(block_start, block_end),
            cache_salt: self.cache_salt,
        }
    }
}

/// Hash chain over all *full* blocks of `tokens`. The trailing partial
/// block (if any) is unhashed — it is never shareable.
pub fn block_hashes(tokens: &[u32], block_size: usize, ctx: &HashContext) -> Vec<BlockHash> {
    assert!(block_size > 0);
    let n_full = tokens.len() / block_size;
    let mut out = Vec::with_capacity(n_full);
    let mut parent: Option<BlockHash> = None;
    for b in 0..n_full {
        let start = b * block_size;
        let end = start + block_size;
        count_hash_op();
        let h = block_hash(parent, &tokens[start..end], ctx.extra_for_block(start, end));
        out.push(h);
        parent = Some(h);
    }
    out
}

/// Incremental form used on the decode path: hash only block `idx` given
/// its parent (avoids rehashing the whole prefix each step).
pub fn next_block_hash(
    parent: Option<BlockHash>,
    tokens: &[u32],
    block_idx: usize,
    block_size: usize,
    ctx: &HashContext,
) -> BlockHash {
    let start = block_idx * block_size;
    let end = start + block_size;
    count_hash_op();
    block_hash(parent, &tokens[start..end], ctx.extra_for_block(start, end))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| i * 7 + 3).collect()
    }

    fn alora_ctx(inv_start: usize, base_aligned: bool) -> HashContext {
        HashContext {
            adapter_id: Some(2),
            is_alora: true,
            inv_start,
            base_aligned,
            cache_salt: 0,
        }
    }

    #[test]
    fn partial_tail_block_not_hashed() {
        let t = toks(40); // 2.5 blocks of 16
        let hs = block_hashes(&t, 16, &HashContext::base());
        assert_eq!(hs.len(), 2);
    }

    #[test]
    fn base_aligned_prefix_matches_base_model() {
        // aLoRA activated at token 40: blocks 0,1 (ending at 16,32) are
        // pre-activation -> identical hashes to a base request; block 2
        // (ending 48 > 40) is salted -> differs.
        let t = toks(48);
        let base = block_hashes(&t, 16, &HashContext::base());
        let alora = block_hashes(&t, 16, &alora_ctx(40, true));
        assert_eq!(base[0], alora[0]);
        assert_eq!(base[1], alora[1]);
        assert_ne!(base[2], alora[2]);
    }

    #[test]
    fn vanilla_vllm_isolates_every_block() {
        let t = toks(48);
        let base = block_hashes(&t, 16, &HashContext::base());
        let alora = block_hashes(&t, 16, &alora_ctx(40, false));
        for i in 0..3 {
            assert_ne!(base[i], alora[i], "block {i} must be salted w/o feature");
        }
    }

    #[test]
    fn standard_lora_always_salted_even_with_feature() {
        let t = toks(32);
        let base = block_hashes(&t, 16, &HashContext::base());
        let lora = block_hashes(
            &t,
            16,
            &HashContext {
                adapter_id: Some(1),
                is_alora: false,
                inv_start: 0,
                base_aligned: true,
                cache_salt: 0,
            },
        );
        assert_ne!(base[0], lora[0]);
        assert_ne!(base[1], lora[1]);
    }

    #[test]
    fn straddling_block_is_salted() {
        // activation at 20, block [16, 32) contains post-activation tokens.
        let t = toks(32);
        let base = block_hashes(&t, 16, &HashContext::base());
        let alora = block_hashes(&t, 16, &alora_ctx(20, true));
        assert_eq!(base[0], alora[0]);
        assert_ne!(base[1], alora[1]);
    }

    #[test]
    fn activation_on_block_boundary() {
        let t = toks(32);
        let alora = block_hashes(&t, 16, &alora_ctx(32, true));
        let base = block_hashes(&t, 16, &HashContext::base());
        // boundary: block ending exactly AT inv_start is pre-activation
        assert_eq!(base[1], alora[1]);
    }

    #[test]
    fn two_aloras_share_pre_activation_blocks() {
        let t = toks(48);
        let a = block_hashes(
            &t,
            16,
            &HashContext { adapter_id: Some(0), ..alora_ctx(40, true) },
        );
        let b = block_hashes(
            &t,
            16,
            &HashContext { adapter_id: Some(1), ..alora_ctx(40, true) },
        );
        assert_eq!(a[0], b[0]);
        assert_eq!(a[1], b[1]);
        assert_ne!(a[2], b[2], "post-activation blocks stay adapter-private");
    }

    #[test]
    fn incremental_matches_batch() {
        let t = toks(64);
        let ctx = alora_ctx(33, true);
        let batch = block_hashes(&t, 16, &ctx);
        let mut parent = None;
        for (i, expected) in batch.iter().enumerate() {
            let h = next_block_hash(parent, &t, i, 16, &ctx);
            assert_eq!(h, *expected, "block {i}");
            parent = Some(h);
        }
    }

    #[test]
    fn property_prefix_stability() {
        // Appending tokens never changes earlier block hashes.
        use crate::util::prop;
        prop::check("prefix-stability", 30, |rng, _| {
            let n1 = rng.range(16, 128) as usize & !15;
            let n2 = n1 + (rng.range(16, 64) as usize & !15);
            let mut t = toks(n2);
            for x in t.iter_mut() {
                *x = rng.next_below(1000) as u32;
            }
            let ctx = HashContext::base();
            let short = block_hashes(&t[..n1], 16, &ctx);
            let long = block_hashes(&t, 16, &ctx);
            if long[..short.len()] != short[..] {
                return Err("prefix hashes changed after append".into());
            }
            Ok(())
        });
    }
}
