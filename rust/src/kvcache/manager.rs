//! KV-cache manager: per-request block tables over the shared [`BlockPool`].
//!
//! The vLLM pattern (Figure 2): the scheduler consults this manager to
//! (a) find how much of an incoming request's prompt is already cached
//! (automatic prefix caching), (b) allocate physical blocks as the request
//! prefills/decodes, and (c) commit content hashes when blocks fill so
//! later requests can reuse them. Whether *cross-model* hits occur is
//! decided entirely by the hash chain the request presents
//! (prefix::HashContext) — this module is policy-free.

use crate::util::fxmap::FxHashMap;

use super::block::{BlockHash, BlockId, BlockPool, PoolStats};
use super::summary::HashSummary;

/// Opaque request key (the engine's RequestId.0).
pub type ReqKey = u64;

#[derive(Debug)]
struct RequestBlocks {
    blocks: Vec<BlockId>,
    /// How many leading blocks carry committed (shareable) hashes.
    committed: usize,
    /// Tokens covered by cache hits at admission (for hit-rate metrics).
    cached_tokens: usize,
}

/// Outcome of admitting a request: how much prefix was already cached.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CachedPrefix {
    pub blocks: usize,
    pub tokens: usize,
}

/// Aggregate counters for Table-2's "Cache Hit Rate" row.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub pool: PoolStats,
    /// Tokens requested for prefill across all admitted requests.
    pub prefix_tokens_queried: u64,
    /// Tokens served from cache at admission.
    pub prefix_tokens_hit: u64,
    pub preemptions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.prefix_tokens_queried == 0 {
            0.0
        } else {
            self.prefix_tokens_hit as f64 / self.prefix_tokens_queried as f64
        }
    }
}

#[derive(Debug)]
pub struct KvCacheManager {
    pool: BlockPool,
    block_size: usize,
    enable_prefix_caching: bool,
    tables: FxHashMap<ReqKey, RequestBlocks>,
    stats: CacheStats,
}

impl KvCacheManager {
    pub fn new(num_blocks: u32, block_size: u32, enable_prefix_caching: bool) -> Self {
        KvCacheManager {
            pool: BlockPool::new(num_blocks),
            block_size: block_size as usize,
            enable_prefix_caching,
            tables: FxHashMap::default(),
            stats: CacheStats::default(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_free_blocks(&self) -> u32 {
        self.pool.num_free()
    }

    pub fn num_total_blocks(&self) -> u32 {
        self.pool.num_blocks()
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = self.stats;
        s.pool = self.pool.stats();
        s
    }

    /// Routable view of the committed hashes: what this cache could serve a
    /// hash chain from, as a compact summary a cluster router can score
    /// against (fed by the pool's commit/eviction events — no pool walk).
    pub fn routing_summary(&self) -> &HashSummary {
        self.pool.routing_summary()
    }

    /// The unified memory ledger (KV pages vs resident adapter weights).
    pub fn budget(&self) -> &crate::memory::MemoryBudget {
        self.pool.budget()
    }

    /// Claim `n` pages for adapter weights from the shared pool (see
    /// [`BlockPool::claim_blocks`]). Atomic; None under pressure — the
    /// residency manager then evicts idle adapters and retries.
    pub fn claim_adapter_blocks(&mut self, n: usize) -> Option<Vec<BlockId>> {
        self.pool.claim_blocks(n)
    }

    /// Return an evicted adapter's weight pages to the shared pool.
    pub fn release_adapter_blocks(&mut self, blocks: &[BlockId]) {
        self.pool.release_claimed(blocks);
    }

    /// Peek: how many leading blocks of this hash chain are cached right
    /// now? (No refcounts taken; the scheduler uses this to budget tokens.)
    pub fn peek_cached_prefix(&self, hashes: &[BlockHash]) -> CachedPrefix {
        if !self.enable_prefix_caching {
            return CachedPrefix { blocks: 0, tokens: 0 };
        }
        let mut n = 0;
        for h in hashes {
            if self.pool.contains(*h) {
                n += 1;
            } else {
                break;
            }
        }
        CachedPrefix { blocks: n, tokens: n * self.block_size }
    }

    /// Admit a request: take references on every cached prefix block (the
    /// chain prefix that hits), create its block table, and report the
    /// cached span. `prompt_tokens` is used for hit-rate accounting.
    ///
    /// The caller must cap usable cached tokens at prompt_len - 1 (at least
    /// one token must be computed to produce logits); that cap is scheduler
    /// policy, not cache semantics, so it lives there.
    pub fn start_request(
        &mut self,
        key: ReqKey,
        hashes: &[BlockHash],
        prompt_tokens: usize,
    ) -> CachedPrefix {
        assert!(
            !self.tables.contains_key(&key),
            "request {key} already has a block table"
        );
        let mut blocks = Vec::new();
        if self.enable_prefix_caching {
            for h in hashes {
                match self.pool.lookup(*h) {
                    Some(b) => blocks.push(b),
                    None => break,
                }
            }
        }
        let cached = CachedPrefix {
            blocks: blocks.len(),
            tokens: blocks.len() * self.block_size,
        };
        self.stats.prefix_tokens_queried += prompt_tokens as u64;
        self.stats.prefix_tokens_hit += cached.tokens.min(prompt_tokens) as u64;
        let committed = blocks.len(); // hit blocks are committed by definition
        self.tables.insert(
            key,
            RequestBlocks { blocks, committed, cached_tokens: cached.tokens },
        );
        cached
    }

    /// Grow the request's table to cover `total_tokens`. Atomic: either all
    /// needed blocks are allocated or none (returns false -> caller must
    /// preempt or wait).
    pub fn ensure_capacity(&mut self, key: ReqKey, total_tokens: usize) -> bool {
        let needed_blocks = total_tokens.div_ceil(self.block_size);
        let table = self.tables.get_mut(&key).expect("unknown request");
        if needed_blocks <= table.blocks.len() {
            return true;
        }
        let missing = needed_blocks - table.blocks.len();
        if (self.pool.num_free() as usize) < missing {
            return false;
        }
        for _ in 0..missing {
            let b = self.pool.alloc().expect("free count said yes");
            table.blocks.push(b);
        }
        true
    }

    /// Number of *new* blocks `ensure_capacity(total_tokens)` would need.
    pub fn blocks_needed(&self, key: ReqKey, total_tokens: usize) -> usize {
        let needed = total_tokens.div_ceil(self.block_size);
        let have = self.tables.get(&key).map(|t| t.blocks.len()).unwrap_or(0);
        needed.saturating_sub(have)
    }

    /// Commit hashes for blocks that have become full. `hashes` is the full
    /// chain for the request's current token stream; only yet-uncommitted
    /// positions covered by the table are committed.
    pub fn commit_full_blocks(&mut self, key: ReqKey, hashes: &[BlockHash]) {
        if !self.enable_prefix_caching {
            return;
        }
        let table = self.tables.get_mut(&key).expect("unknown request");
        let upto = hashes.len().min(table.blocks.len());
        for i in table.committed..upto {
            self.pool.commit_hash(table.blocks[i], hashes[i]);
        }
        table.committed = table.committed.max(upto);
    }

    /// The request's current physical block table (for executors).
    pub fn blocks_of(&self, key: ReqKey) -> &[BlockId] {
        &self.tables.get(&key).expect("unknown request").blocks
    }

    pub fn cached_tokens_of(&self, key: ReqKey) -> usize {
        self.tables.get(&key).map(|t| t.cached_tokens).unwrap_or(0)
    }

    pub fn has_request(&self, key: ReqKey) -> bool {
        self.tables.contains_key(&key)
    }

    /// Release all blocks. Tail blocks are freed FIRST so that, in the LRU
    /// free list, deep suffix blocks get evicted before the shared prefix —
    /// matching vLLM's reversed-free policy that keeps common prefixes hot.
    pub fn free_request(&mut self, key: ReqKey) {
        let table = self.tables.remove(&key).expect("unknown request");
        for b in table.blocks.into_iter().rev() {
            self.pool.free(b);
        }
    }

    /// Preemption: same as free, but counted (the request will re-prefill
    /// later — possibly hitting whatever of its blocks survive).
    pub fn preempt_request(&mut self, key: ReqKey) {
        self.stats.preemptions += 1;
        self.free_request(key);
    }

    /// Test hook: full invariant sweep.
    #[doc(hidden)]
    pub fn check_invariants(&self) -> Result<(), String> {
        self.pool.check_invariants()?;
        for (k, t) in &self.tables {
            if t.committed > t.blocks.len() {
                return Err(format!("req {k}: committed > blocks"));
            }
            for b in &t.blocks {
                if self.pool.ref_count(*b) == 0 {
                    return Err(format!("req {k}: table holds freed block {b:?}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::prefix::{block_hashes, HashContext};

    fn toks(n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| i * 3 + 1).collect()
    }

    fn mgr(blocks: u32) -> KvCacheManager {
        KvCacheManager::new(blocks, 16, true)
    }

    #[test]
    fn cold_start_no_hits_then_warm_hits() {
        let mut m = mgr(16);
        let t = toks(64);
        let hs = block_hashes(&t, 16, &HashContext::base());

        let c = m.start_request(1, &hs, 64);
        assert_eq!(c.blocks, 0);
        assert!(m.ensure_capacity(1, 64));
        m.commit_full_blocks(1, &hs);
        m.free_request(1);

        // Second identical request: full prefix hit from the free pool.
        let c2 = m.start_request(2, &hs, 64);
        assert_eq!(c2, CachedPrefix { blocks: 4, tokens: 64 });
        assert!((m.stats().hit_rate() - 0.5).abs() < 1e-9); // 64 of 128
        m.free_request(2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_sharing_refcounts() {
        let mut m = mgr(16);
        let t = toks(32);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &hs, 32);
        assert!(m.ensure_capacity(1, 32));
        m.commit_full_blocks(1, &hs);
        // Request 2 shares the blocks while 1 is still running.
        let c = m.start_request(2, &hs, 32);
        assert_eq!(c.blocks, 2);
        let b0 = m.blocks_of(1)[0];
        assert_eq!(m.blocks_of(2)[0], b0, "same physical block shared");
        m.free_request(1);
        // Still referenced by request 2; must not be reallocatable.
        assert_eq!(m.blocks_of(2).len(), 2);
        m.free_request(2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn capacity_is_atomic() {
        let mut m = mgr(4);
        let t = toks(64);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &hs, 64);
        assert!(m.ensure_capacity(1, 64)); // exactly 4 blocks
        m.start_request(2, &hs[..0], 64);
        assert!(!m.ensure_capacity(2, 32), "no free blocks left");
        assert_eq!(m.blocks_of(2).len(), 0, "failed alloc leaves no residue");
        m.free_request(1);
        assert!(m.ensure_capacity(2, 32));
        m.check_invariants().unwrap();
    }

    #[test]
    fn partial_tail_never_committed() {
        let mut m = mgr(8);
        let t = toks(40); // 2 full + partial
        let hs = block_hashes(&t, 16, &HashContext::base());
        assert_eq!(hs.len(), 2);
        m.start_request(1, &hs, 40);
        assert!(m.ensure_capacity(1, 40)); // 3 blocks
        m.commit_full_blocks(1, &hs);
        m.free_request(1);
        let c = m.start_request(2, &hs, 40);
        assert_eq!(c.blocks, 2, "only full blocks reusable");
        m.free_request(2);
    }

    #[test]
    fn cross_model_reuse_via_hash_equality() {
        // The contribution, end-to-end at the manager level: base prefills,
        // aLoRA's pre-activation chain produces THE SAME hashes, so
        // admission hits. LoRA's salted chain misses.
        let mut m = mgr(16);
        let prompt = toks(64);
        let base_hs = block_hashes(&prompt, 16, &HashContext::base());
        m.start_request(1, &base_hs, 64);
        assert!(m.ensure_capacity(1, 64));
        m.commit_full_blocks(1, &base_hs);
        m.free_request(1);

        // aLoRA over prompt + invocation (activation at 64): pre-activation
        // hashes equal base → 4 hits.
        let mut ev = prompt.clone();
        ev.extend_from_slice(&[500, 501, 502, 503]);
        let alora_ctx = HashContext {
            adapter_id: Some(1),
            is_alora: true,
            inv_start: 64,
            base_aligned: true,
            cache_salt: 0,
        };
        let alora_hs = block_hashes(&ev, 16, &alora_ctx);
        let c = m.start_request(2, &alora_hs, ev.len());
        assert_eq!(c.blocks, 4, "aLoRA reuses base blocks");
        m.free_request(2);

        // Standard LoRA (always salted): zero hits.
        let lora_ctx = HashContext {
            adapter_id: Some(1),
            is_alora: false,
            inv_start: 0,
            base_aligned: true,
            cache_salt: 0,
        };
        let lora_hs = block_hashes(&ev, 16, &lora_ctx);
        let c = m.start_request(3, &lora_hs, ev.len());
        assert_eq!(c.blocks, 0, "LoRA cannot reuse base blocks");
        m.free_request(3);
    }

    #[test]
    fn reverse_direction_reuse_alora_to_base() {
        let mut m = mgr(16);
        let prompt = toks(48);
        let alora_ctx = HashContext {
            adapter_id: Some(0),
            is_alora: true,
            inv_start: 48,
            base_aligned: true,
            cache_salt: 0,
        };
        // aLoRA prefills the conversation (all blocks pre-activation).
        let a_hs = block_hashes(&prompt, 16, &alora_ctx);
        m.start_request(1, &a_hs, 48);
        assert!(m.ensure_capacity(1, 48));
        m.commit_full_blocks(1, &a_hs);
        m.free_request(1);
        // Base model hits everything.
        let b_hs = block_hashes(&prompt, 16, &HashContext::base());
        let c = m.start_request(2, &b_hs, 48);
        assert_eq!(c.blocks, 3);
        m.free_request(2);
    }

    #[test]
    fn disabled_prefix_caching_never_hits() {
        let mut m = KvCacheManager::new(8, 16, false);
        let t = toks(32);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &hs, 32);
        assert!(m.ensure_capacity(1, 32));
        m.commit_full_blocks(1, &hs);
        m.free_request(1);
        let c = m.start_request(2, &hs, 32);
        assert_eq!(c.blocks, 0);
    }

    #[test]
    fn eviction_under_pressure_loses_oldest_prefix() {
        let mut m = mgr(4);
        let t1 = toks(32);
        let hs1 = block_hashes(&t1, 16, &HashContext::base());
        m.start_request(1, &hs1, 32);
        assert!(m.ensure_capacity(1, 32));
        m.commit_full_blocks(1, &hs1);
        m.free_request(1);
        // A different 64-token request needs all 4 blocks → evicts t1's.
        let t2: Vec<u32> = (0..64).map(|i| 1000 + i).collect();
        let hs2 = block_hashes(&t2, 16, &HashContext::base());
        m.start_request(2, &hs2, 64);
        assert!(m.ensure_capacity(2, 64));
        m.commit_full_blocks(2, &hs2);
        m.free_request(2);
        let c = m.start_request(3, &hs1, 32);
        assert_eq!(c.blocks, 0, "t1's blocks were evicted");
        m.free_request(3);
    }

    #[test]
    fn preemption_counted_and_blocks_released() {
        let mut m = mgr(4);
        let t = toks(64);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &hs, 64);
        assert!(m.ensure_capacity(1, 64));
        m.preempt_request(1);
        assert_eq!(m.stats().preemptions, 1);
        assert_eq!(m.num_free_blocks(), 4);
    }

    #[test]
    fn property_random_workload_invariants() {
        use crate::util::prop;
        prop::check("manager-random", 25, |rng, _| {
            let mut m = KvCacheManager::new(rng.range(4, 32) as u32, 16, true);
            let mut live: Vec<(u64, Vec<BlockHash>, usize)> = vec![];
            let mut next_key = 0u64;
            for _ in 0..120 {
                match rng.next_below(3) {
                    0 => {
                        let n = rng.range(1, 6) as usize * 16;
                        let t: Vec<u32> =
                            (0..n).map(|_| rng.next_below(64) as u32).collect();
                        let hs = block_hashes(&t, 16, &HashContext::base());
                        let key = next_key;
                        next_key += 1;
                        m.start_request(key, &hs, n);
                        if m.ensure_capacity(key, n) {
                            m.commit_full_blocks(key, &hs);
                            live.push((key, hs, n));
                        } else {
                            m.free_request(key);
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let i = rng.next_below(live.len() as u64) as usize;
                            let (key, _, _) = live.swap_remove(i);
                            m.free_request(key);
                        }
                    }
                    _ => m.check_invariants()?,
                }
            }
            for (key, _, _) in live {
                m.free_request(key);
            }
            m.check_invariants()?;
            if m.num_free_blocks() != m.num_total_blocks() {
                return Err("blocks leaked".into());
            }
            Ok(())
        });
    }
}
