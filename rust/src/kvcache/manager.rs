//! KV-cache manager: per-request block tables over the shared [`BlockPool`].
//!
//! The vLLM pattern (Figure 2): the scheduler consults this manager to
//! (a) find how much of an incoming request's prompt is already cached
//! (automatic prefix caching), (b) allocate physical blocks as the request
//! prefills/decodes, and (c) commit content hashes when blocks fill so
//! later requests can reuse them. Whether *cross-model* hits occur is
//! decided entirely by the hash chain the request presents
//! (prefix::HashContext) — this module is policy-free.
//!
//! Chains arrive as interned [`ChainRef`] handles (ISSUE 7): admission
//! walks a chain in place without materializing it, a lease verifies the
//! delta-turn extension by node identity in O(delta), and commit reads
//! only the yet-uncommitted suffix.

use crate::util::fxmap::FxHashMap;

use super::block::{BlockHash, BlockId, BlockPool, PoolStats};
use super::chain::ChainRef;
use super::summary::HashSummary;

/// Opaque request key (the engine's RequestId.0).
pub type ReqKey = u64;

#[derive(Debug)]
struct RequestBlocks {
    blocks: Vec<BlockId>,
    /// How many leading blocks carry committed (shareable) hashes.
    committed: usize,
    /// Tokens covered by cache hits at admission (for hit-rate metrics).
    cached_tokens: usize,
}

/// Outcome of admitting a request: how much prefix was already cached.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CachedPrefix {
    pub blocks: usize,
    pub tokens: usize,
}

/// Aggregate counters for Table-2's "Cache Hit Rate" row.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub pool: PoolStats,
    /// Tokens requested for prefill across all admitted requests.
    pub prefix_tokens_queried: u64,
    /// Tokens served from cache at admission.
    pub prefix_tokens_hit: u64,
    pub preemptions: u64,
    /// Session prefix leases taken (each `acquire_lease` call).
    pub leases_acquired: u64,
    /// Blocks pinned across all lease acquisitions.
    pub lease_blocks_pinned: u64,
    /// Leases broken under memory pressure (running work always beats a
    /// parked session's retention).
    pub leases_reclaimed: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.prefix_tokens_queried == 0 {
            0.0
        } else {
            self.prefix_tokens_hit as f64 / self.prefix_tokens_queried as f64
        }
    }
}

#[derive(Debug)]
struct Lease {
    blocks: Vec<BlockId>,
    /// Interned chain covering exactly the pinned blocks (same length), so
    /// a re-acquire whose chain extends it — verified by node identity in
    /// O(delta) — keeps the existing pins and only pins the delta.
    chain: ChainRef,
}

#[derive(Debug)]
pub struct KvCacheManager {
    pool: BlockPool,
    block_size: usize,
    enable_prefix_caching: bool,
    tables: FxHashMap<ReqKey, RequestBlocks>,
    stats: CacheStats,
    /// Session prefix leases: pinned blocks per lease key, so a parked
    /// conversation's chain survives between turns (the v1 sessions API).
    leases: FxHashMap<u64, Lease>,
    /// Lease keys in acquisition order (front = oldest = first broken
    /// under memory pressure).
    lease_order: Vec<u64>,
}

impl KvCacheManager {
    pub fn new(num_blocks: u32, block_size: u32, enable_prefix_caching: bool) -> Self {
        KvCacheManager {
            pool: BlockPool::new(num_blocks),
            block_size: block_size as usize,
            enable_prefix_caching,
            tables: FxHashMap::default(),
            stats: CacheStats::default(),
            leases: FxHashMap::default(),
            lease_order: Vec::new(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_free_blocks(&self) -> u32 {
        self.pool.num_free()
    }

    pub fn num_total_blocks(&self) -> u32 {
        self.pool.num_blocks()
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = self.stats;
        s.pool = self.pool.stats();
        s
    }

    /// Routable view of the committed hashes: what this cache could serve a
    /// hash chain from, as a compact summary a cluster router can score
    /// against (fed by the pool's commit/eviction events — no pool walk).
    pub fn routing_summary(&self) -> &HashSummary {
        self.pool.routing_summary()
    }

    /// The unified memory ledger (KV pages vs resident adapter weights).
    pub fn budget(&self) -> &crate::memory::MemoryBudget {
        self.pool.budget()
    }

    /// Configure the host-tier capacity for demoted adapter weights
    /// (construction-time; DESIGN.md §20). 0 disables the tier.
    pub fn set_host_adapter_blocks(&mut self, blocks: usize) {
        self.pool.budget_mut().set_host_capacity(blocks);
    }

    /// Charge a demoted adapter's weight pages to the host tier. False —
    /// and no charge — when the tier lacks headroom; the residency layer
    /// then drops its host-LRU entries to make room (or gives up and the
    /// demotion becomes a plain drop).
    pub fn charge_host_adapter_blocks(&mut self, n: usize) -> bool {
        self.pool.budget_mut().try_charge_host(n)
    }

    /// Return a promoted (or dropped) adapter's pages from the host tier.
    pub fn release_host_adapter_blocks(&mut self, n: usize) {
        self.pool.budget_mut().release_host(n);
    }

    /// Claim `n` pages for adapter weights from the shared pool (see
    /// [`BlockPool::claim_blocks`]). Atomic; None under pressure — the
    /// residency manager then evicts idle adapters and retries. Session
    /// leases are broken first: pinned-but-parked KV is cheaper to drop
    /// than stalling a weight load (a broken lease costs a re-prefill
    /// later; a stalled load costs admission now).
    pub fn claim_adapter_blocks(&mut self, n: usize) -> Option<Vec<BlockId>> {
        if (self.pool.num_free() as usize) < n {
            self.reclaim_leases(n);
        }
        self.pool.claim_blocks(n)
    }

    // -- session prefix leases ---------------------------------------------

    /// Pin the cached prefix of `chain` under `lease` so the blocks
    /// survive between conversation turns (the v1 sessions API's
    /// retention). Re-acquiring an existing lease replaces it (the chain
    /// grew by one turn). Pinning stops at the first uncached hash —
    /// leases retain what exists, they never allocate. Returns the number
    /// of blocks pinned.
    ///
    /// The delta-turn fast path is zero-copy: `is_extension_of` is an
    /// O(delta) node-identity walk, pinning visits only the unpinned
    /// suffix in place, and the stored lease chain is an O(unpinned-tail)
    /// `prefix` handle — no `Vec<BlockHash>` is ever materialized.
    ///
    /// Leases are best-effort: under allocation pressure they are broken
    /// oldest-first (see [`KvCacheManager::ensure_capacity`]) so a parked
    /// session can never wedge running work.
    pub fn acquire_lease(&mut self, lease: u64, chain: &ChainRef) -> usize {
        if !self.enable_prefix_caching {
            return 0;
        }
        // Fast path: the chain extends the lease's pinned prefix (the
        // append-only conversation grew a turn). Keep the pins, continue
        // from where pinning stopped last time.
        let start = match self.leases.get(&lease) {
            Some(l) if chain.is_extension_of(&l.chain) => l.chain.len(),
            // Diverged chain (salt change / rewrite): full re-pin.
            Some(_) => {
                self.release_lease(lease);
                0
            }
            None => 0,
        };
        let mut new_blocks = Vec::new();
        {
            let pool = &mut self.pool;
            chain.visit_from(start, |h| match pool.pin(h) {
                Some(b) => {
                    new_blocks.push(b);
                    true
                }
                None => false,
            });
        }
        let delta = new_blocks.len();
        self.stats.leases_acquired += 1;
        if start == 0 && delta == 0 {
            // Nothing pinned (chain evicted or sub-block): registering a
            // phantom lease would let pressure reclaim "break" it — a
            // counted reclaim that frees nothing.
            return 0;
        }
        self.stats.lease_blocks_pinned += delta as u64;
        let pinned_chain = chain.prefix(start + delta);
        let entry = self
            .leases
            .entry(lease)
            .or_insert_with(|| Lease { blocks: Vec::new(), chain: ChainRef::empty() });
        entry.chain = pinned_chain;
        entry.blocks.extend(new_blocks);
        let total = entry.blocks.len();
        // A re-acquire freshens the lease's reclaim age.
        self.lease_order.retain(|l| *l != lease);
        self.lease_order.push(lease);
        // Register the full chain (pinned prefix plus any uncached tail)
        // for incremental routing affinity.
        self.pool.track_chain(lease, chain);
        total
    }

    /// Splice a migrated chain into this replica (DESIGN.md §18): the
    /// destination side of `Cluster::migrate_lease`. For each hash in
    /// order, an already-cached block is pinned (the dedup case — the
    /// destination already had some of the prefix committed) and a
    /// missing one is allocated and committed as the transferred KV
    /// lands, stopping at pool exhaustion (a partial prefix is still a
    /// head start). The installed span is registered as a lease exactly
    /// like `acquire_lease` would, so refcounts, the reclaim order, the
    /// routing summary (+1 per newly committed hash) and the tracked
    /// chain all stay symmetric with the native-prefill path. Returns
    /// blocks installed (0 = nothing transferable / caching disabled).
    pub fn install_migrated_lease(&mut self, lease: u64, chain: &ChainRef) -> usize {
        if !self.enable_prefix_caching || chain.is_empty() {
            return 0;
        }
        // A stale local lease under the same key (e.g. a pre-divergence
        // copy) is replaced wholesale, mirroring acquire_lease's diverged
        // path.
        self.release_lease(lease);
        let mut blocks = Vec::new();
        for h in chain.hashes() {
            if let Some(b) = self.pool.pin(h) {
                blocks.push(b);
            } else if let Some(b) = self.pool.alloc() {
                self.pool.commit_hash(b, h);
                blocks.push(b);
            } else {
                break;
            }
        }
        let n = blocks.len();
        if n == 0 {
            return 0;
        }
        self.stats.leases_acquired += 1;
        self.stats.lease_blocks_pinned += n as u64;
        let pinned_chain = chain.prefix(n);
        self.leases.insert(lease, Lease { blocks, chain: pinned_chain });
        self.lease_order.retain(|l| *l != lease);
        self.lease_order.push(lease);
        // Track the full chain for routing affinity, as acquire_lease does.
        self.pool.track_chain(lease, chain);
        n
    }

    /// The chain a lease currently pins (None for unknown keys) — the
    /// source-side read of a migration: which hashes to ship.
    pub fn lease_chain(&self, lease: u64) -> Option<ChainRef> {
        self.leases.get(&lease).map(|l| l.chain.clone())
    }

    /// Release a lease's pins (session deleted, or re-acquire). Unknown
    /// lease keys are a no-op (a cluster broadcasts releases).
    pub fn release_lease(&mut self, lease: u64) {
        if let Some(l) = self.leases.remove(&lease) {
            self.lease_order.retain(|k| *k != lease);
            self.pool.untrack_chain(lease);
            // Tail-first, matching free_request: deep suffix blocks become
            // LRU-evictable before the shared prefix.
            for b in l.blocks.into_iter().rev() {
                self.pool.free(b);
            }
        }
    }

    /// Total blocks currently pinned by leases (shared pins counted per
    /// lease — a gauge, not an ownership ledger).
    pub fn leased_blocks(&self) -> usize {
        self.leases.values().map(|l| l.blocks.len()).sum()
    }

    /// Blocks pinned by this one lease (0 for unknown keys).
    pub fn lease_size(&self, lease: u64) -> usize {
        self.leases.get(&lease).map(|l| l.blocks.len()).unwrap_or(0)
    }

    pub fn num_leases(&self) -> usize {
        self.leases.len()
    }

    /// Every live lease key in acquisition order (oldest first) — the
    /// deterministic enumeration a batched evacuation walks when a
    /// draining replica ships all its parked sessions at once
    /// (DESIGN.md §19). Order matters: it fixes both the destination
    /// round-robin and the op-count of the transfer, so tests can pin it.
    pub fn lease_keys(&self) -> Vec<u64> {
        self.lease_order.clone()
    }

    /// Distinct physical blocks held by leases (for idle-leak accounting:
    /// two sessions sharing a tenant prefix pin the same block twice but
    /// occupy it once).
    pub fn leased_distinct_blocks(&self) -> usize {
        let mut seen = crate::util::fxmap::FxHashSet::default();
        for b in self.leases.values().flat_map(|l| l.blocks.iter()) {
            seen.insert(*b);
        }
        seen.len()
    }

    /// Break leases oldest-first until `need_free` blocks are free or no
    /// leases remain. Freeing a lease's pin only liberates blocks no
    /// running request shares, so the loop keeps going until the target
    /// is met or the lease table is empty.
    fn reclaim_leases(&mut self, need_free: usize) {
        while (self.pool.num_free() as usize) < need_free && !self.lease_order.is_empty() {
            let l = self.lease_order.remove(0);
            if let Some(lease) = self.leases.remove(&l) {
                self.pool.untrack_chain(l);
                for b in lease.blocks.into_iter().rev() {
                    self.pool.free(b);
                }
            }
            self.stats.leases_reclaimed += 1;
        }
    }

    /// Release every lease (replica failure: the pinned blocks are gone
    /// with the device). Returns the orphaned lease keys so the serving
    /// layer can repair the sessions that held them. Not counted as
    /// pressure reclaims — nothing was traded off, the memory died.
    pub fn release_all_leases(&mut self) -> Vec<u64> {
        let keys = std::mem::take(&mut self.lease_order);
        for l in &keys {
            if let Some(lease) = self.leases.remove(l) {
                self.pool.untrack_chain(*l);
                for b in lease.blocks.into_iter().rev() {
                    self.pool.free(b);
                }
            }
        }
        keys
    }

    /// Drop every cached hash (see [`super::block::BlockPool::purge_cached`]).
    /// Only valid once every request table and lease is gone — a failed
    /// replica is evacuated first, then wiped.
    pub fn purge_cached(&mut self) -> usize {
        assert!(
            self.tables.is_empty() && self.leases.is_empty(),
            "purge with live tables/leases"
        );
        self.pool.purge_cached()
    }

    /// Return an evicted adapter's weight pages to the shared pool.
    pub fn release_adapter_blocks(&mut self, blocks: &[BlockId]) {
        self.pool.release_claimed(blocks);
    }

    /// Peek: how many leading blocks of this hash chain are cached right
    /// now? (No refcounts taken; the scheduler uses this to budget tokens.)
    pub fn peek_cached_prefix(&self, chain: &ChainRef) -> CachedPrefix {
        if !self.enable_prefix_caching {
            return CachedPrefix { blocks: 0, tokens: 0 };
        }
        let mut n = 0;
        let pool = &self.pool;
        chain.visit_from(0, |h| {
            if pool.contains(h) {
                n += 1;
                true
            } else {
                false
            }
        });
        CachedPrefix { blocks: n, tokens: n * self.block_size }
    }

    /// Admit a request: take references on every cached prefix block (the
    /// chain prefix that hits), create its block table, and report the
    /// cached span. `prompt_tokens` is used for hit-rate accounting. The
    /// chain is walked in place — never materialized.
    ///
    /// The caller must cap usable cached tokens at prompt_len - 1 (at least
    /// one token must be computed to produce logits); that cap is scheduler
    /// policy, not cache semantics, so it lives there.
    pub fn start_request(
        &mut self,
        key: ReqKey,
        chain: &ChainRef,
        prompt_tokens: usize,
    ) -> CachedPrefix {
        assert!(
            !self.tables.contains_key(&key),
            "request {key} already has a block table"
        );
        let mut blocks = Vec::new();
        if self.enable_prefix_caching {
            let pool = &mut self.pool;
            chain.visit_from(0, |h| match pool.lookup(h) {
                Some(b) => {
                    blocks.push(b);
                    true
                }
                None => false,
            });
        }
        let cached = CachedPrefix {
            blocks: blocks.len(),
            tokens: blocks.len() * self.block_size,
        };
        self.stats.prefix_tokens_queried += prompt_tokens as u64;
        self.stats.prefix_tokens_hit += cached.tokens.min(prompt_tokens) as u64;
        let committed = blocks.len(); // hit blocks are committed by definition
        self.tables.insert(
            key,
            RequestBlocks { blocks, committed, cached_tokens: cached.tokens },
        );
        cached
    }

    /// Grow the request's table to cover `total_tokens`. Atomic: either all
    /// needed blocks are allocated or none (returns false -> caller must
    /// preempt or wait).
    pub fn ensure_capacity(&mut self, key: ReqKey, total_tokens: usize) -> bool {
        let needed_blocks = total_tokens.div_ceil(self.block_size);
        let table = self.tables.get_mut(&key).expect("unknown request");
        if needed_blocks <= table.blocks.len() {
            return true;
        }
        let missing = needed_blocks - table.blocks.len();
        if (self.pool.num_free() as usize) < missing {
            // Running work beats parked sessions: break prefix leases
            // (oldest first) before reporting pressure to the scheduler,
            // whose next escalation (preemption) costs a full re-prefill.
            self.reclaim_leases(missing);
            if (self.pool.num_free() as usize) < missing {
                return false;
            }
        }
        let table = self.tables.get_mut(&key).expect("unknown request");
        for _ in 0..missing {
            let b = self.pool.alloc().expect("free count said yes");
            table.blocks.push(b);
        }
        true
    }

    /// Number of *new* blocks `ensure_capacity(total_tokens)` would need.
    pub fn blocks_needed(&self, key: ReqKey, total_tokens: usize) -> usize {
        let needed = total_tokens.div_ceil(self.block_size);
        let have = self.tables.get(&key).map(|t| t.blocks.len()).unwrap_or(0);
        needed.saturating_sub(have)
    }

    /// Commit hashes for blocks that have become full. `chain` is the full
    /// chain for the request's current token stream; only yet-uncommitted
    /// positions covered by the table are committed — read as an O(delta)
    /// suffix (a first prefill commit is the one honest O(prompt) read).
    pub fn commit_full_blocks(&mut self, key: ReqKey, chain: &ChainRef) {
        if !self.enable_prefix_caching {
            return;
        }
        let table = self.tables.get_mut(&key).expect("unknown request");
        let upto = chain.len().min(table.blocks.len());
        if upto <= table.committed {
            return;
        }
        let start = table.committed;
        for (off, h) in chain.range(start, upto).into_iter().enumerate() {
            self.pool.commit_hash(table.blocks[start + off], h);
        }
        let table = self.tables.get_mut(&key).expect("unknown request");
        table.committed = upto;
    }

    /// The request's current physical block table (for executors).
    pub fn blocks_of(&self, key: ReqKey) -> &[BlockId] {
        &self.tables.get(&key).expect("unknown request").blocks
    }

    pub fn cached_tokens_of(&self, key: ReqKey) -> usize {
        self.tables.get(&key).map(|t| t.cached_tokens).unwrap_or(0)
    }

    pub fn has_request(&self, key: ReqKey) -> bool {
        self.tables.contains_key(&key)
    }

    /// Release all blocks. Tail blocks are freed FIRST so that, in the LRU
    /// free list, deep suffix blocks get evicted before the shared prefix —
    /// matching vLLM's reversed-free policy that keeps common prefixes hot.
    pub fn free_request(&mut self, key: ReqKey) {
        let table = self.tables.remove(&key).expect("unknown request");
        for b in table.blocks.into_iter().rev() {
            self.pool.free(b);
        }
    }

    /// Preemption: same as free, but counted (the request will re-prefill
    /// later — possibly hitting whatever of its blocks survive).
    pub fn preempt_request(&mut self, key: ReqKey) {
        self.stats.preemptions += 1;
        self.free_request(key);
    }

    /// Test hook: full invariant sweep.
    #[doc(hidden)]
    pub fn check_invariants(&self) -> Result<(), String> {
        self.pool.check_invariants()?;
        for (k, t) in &self.tables {
            if t.committed > t.blocks.len() {
                return Err(format!("req {k}: committed > blocks"));
            }
            for b in &t.blocks {
                if self.pool.ref_count(*b) == 0 {
                    return Err(format!("req {k}: table holds freed block {b:?}"));
                }
            }
        }
        if self.leases.len() != self.lease_order.len() {
            return Err(format!(
                "lease table holds {} leases but order tracks {}",
                self.leases.len(),
                self.lease_order.len()
            ));
        }
        for (l, lease) in &self.leases {
            if !self.lease_order.contains(l) {
                return Err(format!("lease {l} missing from reclaim order"));
            }
            if lease.chain.len() != lease.blocks.len() {
                return Err(format!(
                    "lease {l}: {} pinned blocks but {} recorded hashes",
                    lease.blocks.len(),
                    lease.chain.len()
                ));
            }
            for b in &lease.blocks {
                if self.pool.ref_count(*b) == 0 {
                    return Err(format!("lease {l} pins freed block {b:?}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::prefix::{block_hashes, HashContext};

    fn toks(n: usize) -> Vec<u32> {
        (0..n as u32).map(|i| i * 3 + 1).collect()
    }

    fn mgr(blocks: u32) -> KvCacheManager {
        KvCacheManager::new(blocks, 16, true)
    }

    /// Intern a hash slice (tests model chains as Vecs for readability;
    /// production code holds ChainRefs end to end).
    fn ch(hs: &[BlockHash]) -> ChainRef {
        ChainRef::from_hashes(hs)
    }

    #[test]
    fn cold_start_no_hits_then_warm_hits() {
        let mut m = mgr(16);
        let t = toks(64);
        let hs = block_hashes(&t, 16, &HashContext::base());

        let c = m.start_request(1, &ch(&hs), 64);
        assert_eq!(c.blocks, 0);
        assert!(m.ensure_capacity(1, 64));
        m.commit_full_blocks(1, &ch(&hs));
        m.free_request(1);

        // Second identical request: full prefix hit from the free pool.
        let c2 = m.start_request(2, &ch(&hs), 64);
        assert_eq!(c2, CachedPrefix { blocks: 4, tokens: 64 });
        assert!((m.stats().hit_rate() - 0.5).abs() < 1e-9); // 64 of 128
        m.free_request(2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_sharing_refcounts() {
        let mut m = mgr(16);
        let t = toks(32);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &ch(&hs), 32);
        assert!(m.ensure_capacity(1, 32));
        m.commit_full_blocks(1, &ch(&hs));
        // Request 2 shares the blocks while 1 is still running.
        let c = m.start_request(2, &ch(&hs), 32);
        assert_eq!(c.blocks, 2);
        let b0 = m.blocks_of(1)[0];
        assert_eq!(m.blocks_of(2)[0], b0, "same physical block shared");
        m.free_request(1);
        // Still referenced by request 2; must not be reallocatable.
        assert_eq!(m.blocks_of(2).len(), 2);
        m.free_request(2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn capacity_is_atomic() {
        let mut m = mgr(4);
        let t = toks(64);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &ch(&hs), 64);
        assert!(m.ensure_capacity(1, 64)); // exactly 4 blocks
        m.start_request(2, &ChainRef::empty(), 64);
        assert!(!m.ensure_capacity(2, 32), "no free blocks left");
        assert_eq!(m.blocks_of(2).len(), 0, "failed alloc leaves no residue");
        m.free_request(1);
        assert!(m.ensure_capacity(2, 32));
        m.check_invariants().unwrap();
    }

    #[test]
    fn partial_tail_never_committed() {
        let mut m = mgr(8);
        let t = toks(40); // 2 full + partial
        let hs = block_hashes(&t, 16, &HashContext::base());
        assert_eq!(hs.len(), 2);
        m.start_request(1, &ch(&hs), 40);
        assert!(m.ensure_capacity(1, 40)); // 3 blocks
        m.commit_full_blocks(1, &ch(&hs));
        m.free_request(1);
        let c = m.start_request(2, &ch(&hs), 40);
        assert_eq!(c.blocks, 2, "only full blocks reusable");
        m.free_request(2);
    }

    #[test]
    fn cross_model_reuse_via_hash_equality() {
        // The contribution, end-to-end at the manager level: base prefills,
        // aLoRA's pre-activation chain produces THE SAME hashes, so
        // admission hits. LoRA's salted chain misses.
        let mut m = mgr(16);
        let prompt = toks(64);
        let base_hs = block_hashes(&prompt, 16, &HashContext::base());
        m.start_request(1, &ch(&base_hs), 64);
        assert!(m.ensure_capacity(1, 64));
        m.commit_full_blocks(1, &ch(&base_hs));
        m.free_request(1);

        // aLoRA over prompt + invocation (activation at 64): pre-activation
        // hashes equal base → 4 hits.
        let mut ev = prompt.clone();
        ev.extend_from_slice(&[500, 501, 502, 503]);
        let alora_ctx = HashContext {
            adapter_id: Some(1),
            is_alora: true,
            inv_start: 64,
            base_aligned: true,
            cache_salt: 0,
        };
        let alora_hs = block_hashes(&ev, 16, &alora_ctx);
        let c = m.start_request(2, &ch(&alora_hs), ev.len());
        assert_eq!(c.blocks, 4, "aLoRA reuses base blocks");
        m.free_request(2);

        // Standard LoRA (always salted): zero hits.
        let lora_ctx = HashContext {
            adapter_id: Some(1),
            is_alora: false,
            inv_start: 0,
            base_aligned: true,
            cache_salt: 0,
        };
        let lora_hs = block_hashes(&ev, 16, &lora_ctx);
        let c = m.start_request(3, &ch(&lora_hs), ev.len());
        assert_eq!(c.blocks, 0, "LoRA cannot reuse base blocks");
        m.free_request(3);
    }

    #[test]
    fn reverse_direction_reuse_alora_to_base() {
        let mut m = mgr(16);
        let prompt = toks(48);
        let alora_ctx = HashContext {
            adapter_id: Some(0),
            is_alora: true,
            inv_start: 48,
            base_aligned: true,
            cache_salt: 0,
        };
        // aLoRA prefills the conversation (all blocks pre-activation).
        let a_hs = block_hashes(&prompt, 16, &alora_ctx);
        m.start_request(1, &ch(&a_hs), 48);
        assert!(m.ensure_capacity(1, 48));
        m.commit_full_blocks(1, &ch(&a_hs));
        m.free_request(1);
        // Base model hits everything.
        let b_hs = block_hashes(&prompt, 16, &HashContext::base());
        let c = m.start_request(2, &ch(&b_hs), 48);
        assert_eq!(c.blocks, 3);
        m.free_request(2);
    }

    #[test]
    fn disabled_prefix_caching_never_hits() {
        let mut m = KvCacheManager::new(8, 16, false);
        let t = toks(32);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &ch(&hs), 32);
        assert!(m.ensure_capacity(1, 32));
        m.commit_full_blocks(1, &ch(&hs));
        m.free_request(1);
        let c = m.start_request(2, &ch(&hs), 32);
        assert_eq!(c.blocks, 0);
    }

    #[test]
    fn eviction_under_pressure_loses_oldest_prefix() {
        let mut m = mgr(4);
        let t1 = toks(32);
        let hs1 = block_hashes(&t1, 16, &HashContext::base());
        m.start_request(1, &ch(&hs1), 32);
        assert!(m.ensure_capacity(1, 32));
        m.commit_full_blocks(1, &ch(&hs1));
        m.free_request(1);
        // A different 64-token request needs all 4 blocks → evicts t1's.
        let t2: Vec<u32> = (0..64).map(|i| 1000 + i).collect();
        let hs2 = block_hashes(&t2, 16, &HashContext::base());
        m.start_request(2, &ch(&hs2), 64);
        assert!(m.ensure_capacity(2, 64));
        m.commit_full_blocks(2, &ch(&hs2));
        m.free_request(2);
        let c = m.start_request(3, &ch(&hs1), 32);
        assert_eq!(c.blocks, 0, "t1's blocks were evicted");
        m.free_request(3);
    }

    #[test]
    fn preemption_counted_and_blocks_released() {
        let mut m = mgr(4);
        let t = toks(64);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &ch(&hs), 64);
        assert!(m.ensure_capacity(1, 64));
        m.preempt_request(1);
        assert_eq!(m.stats().preemptions, 1);
        assert_eq!(m.num_free_blocks(), 4);
    }

    #[test]
    fn lease_pins_prefix_across_eviction_pressure() {
        // 8-block pool. A conversation's 4 committed blocks, freed, would
        // normally be evicted by 4 blocks of fresh traffic + reuse demand;
        // a lease pins them so an identical follow-up still hits.
        let mut m = mgr(8);
        let t = toks(64);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &ch(&hs), 64);
        assert!(m.ensure_capacity(1, 64));
        m.commit_full_blocks(1, &ch(&hs));
        m.free_request(1);
        assert_eq!(m.acquire_lease(7, &ch(&hs)), 4);
        assert_eq!(m.leased_blocks(), 4);
        assert_eq!(m.lease_size(7), 4);
        // Fresh traffic churns the remaining 4 blocks twice over: every
        // unpinned cached block is gone, the leased 4 survive.
        for round in 0..2u32 {
            let t2: Vec<u32> = (0..64).map(|i| 10_000 + round * 100 + i).collect();
            let hs2 = block_hashes(&t2, 16, &HashContext::base());
            m.start_request(100 + round as u64, &ch(&hs2), 64);
            assert!(m.ensure_capacity(100 + round as u64, 64));
            m.commit_full_blocks(100 + round as u64, &ch(&hs2));
            m.free_request(100 + round as u64);
        }
        let c = m.start_request(2, &ch(&hs), 64);
        assert_eq!(c.blocks, 4, "leased prefix survived the churn");
        m.free_request(2);
        m.release_lease(7);
        assert_eq!(m.leased_blocks(), 0);
        m.check_invariants().unwrap();
        // Re-leasing after release and with the hashes evicted pins 0.
        let t3: Vec<u32> = (0..128).map(|i| 90_000 + i).collect();
        let hs3 = block_hashes(&t3, 16, &HashContext::base());
        m.start_request(3, &ch(&hs3), 128);
        assert!(m.ensure_capacity(3, 128));
        m.commit_full_blocks(3, &ch(&hs3));
        m.free_request(3);
        assert_eq!(m.acquire_lease(7, &ch(&hs)), 0, "chain evicted: nothing to pin");
        m.check_invariants().unwrap();
    }

    #[test]
    fn leases_break_oldest_first_under_allocation_pressure() {
        // 4-block pool fully leased: an incoming request must reclaim the
        // leases (oldest first) rather than fail — running work always
        // beats parked sessions.
        let mut m = mgr(4);
        let a = toks(32);
        let ha = block_hashes(&a, 16, &HashContext::base());
        m.start_request(1, &ch(&ha), 32);
        assert!(m.ensure_capacity(1, 32));
        m.commit_full_blocks(1, &ch(&ha));
        m.free_request(1);
        let b: Vec<u32> = (0..32).map(|i| 5000 + i).collect();
        let hb = block_hashes(&b, 16, &HashContext::base());
        m.start_request(2, &ch(&hb), 32);
        assert!(m.ensure_capacity(2, 32));
        m.commit_full_blocks(2, &ch(&hb));
        m.free_request(2);
        assert_eq!(m.acquire_lease(1, &ch(&ha)), 2); // older lease
        assert_eq!(m.acquire_lease(2, &ch(&hb)), 2); // newer lease
        assert_eq!(m.num_free_blocks(), 0);
        // A 3-block request: breaking lease 1 frees 2, still short, so
        // lease 2 breaks too.
        let c: Vec<u32> = (0..48).map(|i| 9000 + i).collect();
        let hc = block_hashes(&c, 16, &HashContext::base());
        m.start_request(3, &ch(&hc), 48);
        assert!(m.ensure_capacity(3, 48), "leases reclaimed to make room");
        assert_eq!(m.stats().leases_reclaimed, 2);
        assert_eq!(m.num_leases(), 0);
        m.free_request(3);
        m.check_invariants().unwrap();
        assert_eq!(m.num_free_blocks(), 4);
    }

    #[test]
    fn lease_break_path_keeps_routing_summary_symmetric() {
        // Audit pin (ISSUE 5 satellite): blocks freed by the lease-break
        // path (`ensure_capacity` → `reclaim_leases`) must feed the
        // routing summary exactly like normal frees — the hash stays
        // routable until a real eviction emits the −1, and a full churn
        // drives the sketch back to exactly zero. A drifted summary would
        // silently mis-route PrefixAffinity.
        let mut m = mgr(4);
        let t = toks(64);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &ch(&hs), 64);
        assert!(m.ensure_capacity(1, 64));
        m.commit_full_blocks(1, &ch(&hs));
        m.free_request(1);
        assert_eq!(m.routing_summary().committed_blocks(), 4);
        assert_eq!(m.acquire_lease(9, &ch(&hs)), 4);
        m.check_invariants().unwrap();
        // Pressure: a 4-block request breaks the lease. The chain is still
        // cached (break ≠ evict — the blocks go back to the free list with
        // hashes intact), so the summary must NOT lose entries yet...
        let t2: Vec<u32> = (0..64).map(|i| 70_000 + i).collect();
        let hs2 = block_hashes(&t2, 16, &HashContext::base());
        m.start_request(2, &ch(&hs2), 64);
        assert!(m.ensure_capacity(2, 64), "lease reclaimed to make room");
        assert_eq!(m.stats().leases_reclaimed, 1);
        assert_eq!(m.num_leases(), 0);
        // ...and the −1s fire at the allocations that overwrote the broken
        // lease's blocks: committed count now reflects only what survived.
        m.check_invariants().unwrap();
        assert_eq!(m.routing_summary().matching_prefix(&hs), 0, "chain evicted");
        m.commit_full_blocks(2, &ch(&hs2));
        m.free_request(2);
        m.check_invariants().unwrap();
        assert_eq!(m.routing_summary().committed_blocks(), 4);
        // Full churn back to zero: every +1 has met exactly one −1.
        let t3: Vec<u32> = (0..64).map(|i| 80_000 + i).collect();
        let hs3 = block_hashes(&t3, 16, &HashContext::base());
        m.start_request(3, &ch(&hs3), 64);
        assert!(m.ensure_capacity(3, 64));
        m.free_request(3); // uncommitted: hashless frees
        m.check_invariants().unwrap();
        assert_eq!(m.routing_summary().committed_blocks(), 0);
        for &h in &hs {
            assert!(!m.routing_summary().maybe_contains(h), "{h:?} lingers");
        }
        for &h in &hs2 {
            assert!(!m.routing_summary().maybe_contains(h), "{h:?} lingers");
        }
    }

    #[test]
    fn release_all_leases_and_purge_empty_the_replica() {
        // The failover wipe: every lease dropped (keys reported), every
        // cached hash purged with symmetric summary −1s, pool all-free.
        let mut m = mgr(8);
        let a = toks(32);
        let ha = block_hashes(&a, 16, &HashContext::base());
        m.start_request(1, &ch(&ha), 32);
        assert!(m.ensure_capacity(1, 32));
        m.commit_full_blocks(1, &ch(&ha));
        m.free_request(1);
        let b: Vec<u32> = (0..32).map(|i| 5_000 + i).collect();
        let hb = block_hashes(&b, 16, &HashContext::base());
        m.start_request(2, &ch(&hb), 32);
        assert!(m.ensure_capacity(2, 32));
        m.commit_full_blocks(2, &ch(&hb));
        m.free_request(2);
        assert_eq!(m.acquire_lease(11, &ch(&ha)), 2);
        assert_eq!(m.acquire_lease(22, &ch(&hb)), 2);
        let mut keys = m.release_all_leases();
        keys.sort_unstable();
        assert_eq!(keys, vec![11, 22]);
        assert_eq!(m.num_leases(), 0);
        assert_eq!(m.leased_blocks(), 0);
        assert_eq!(m.stats().leases_reclaimed, 0, "failure is not pressure");
        let evictions_before = m.stats().pool.evictions;
        assert_eq!(m.purge_cached(), 4);
        assert_eq!(
            m.stats().pool.evictions,
            evictions_before,
            "a failure wipe is not pressure: evictions untouched"
        );
        m.check_invariants().unwrap();
        assert_eq!(m.routing_summary().committed_blocks(), 0);
        assert_eq!(m.num_free_blocks(), 8);
        assert_eq!(m.start_request(3, &ch(&ha), 32).blocks, 0, "cache reads empty");
        m.free_request(3);
    }

    #[test]
    fn shared_lease_pins_count_distinct_blocks_once() {
        let mut m = mgr(8);
        let t = toks(32);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &ch(&hs), 32);
        assert!(m.ensure_capacity(1, 32));
        m.commit_full_blocks(1, &ch(&hs));
        m.free_request(1);
        assert_eq!(m.acquire_lease(10, &ch(&hs)), 2);
        assert_eq!(m.acquire_lease(11, &ch(&hs)), 2);
        assert_eq!(m.leased_blocks(), 4, "per-lease gauge double counts");
        assert_eq!(m.leased_distinct_blocks(), 2, "physical occupancy doesn't");
        m.release_lease(10);
        m.release_lease(11);
        m.check_invariants().unwrap();
    }

    #[test]
    fn reacquire_extends_lease_pins_only_the_delta() {
        let mut m = mgr(16);
        let t = toks(64);
        let hs = block_hashes(&t, 16, &HashContext::base());
        m.start_request(1, &ch(&hs), 64);
        assert!(m.ensure_capacity(1, 64));
        m.commit_full_blocks(1, &ch(&hs));
        m.free_request(1);
        assert_eq!(m.acquire_lease(7, &ch(&hs)), 4);
        assert_eq!(m.stats().lease_blocks_pinned, 4);

        // The conversation grows a 2-block turn; commit the new tail.
        let mut t2 = t.clone();
        t2.extend((0..32).map(|i| 7_000 + i as u32));
        let hs2 = block_hashes(&t2, 16, &HashContext::base());
        assert_eq!(hs2[..4], hs[..], "chain is prefix-stable");
        m.start_request(2, &ch(&hs2), 96);
        assert!(m.ensure_capacity(2, 96));
        m.commit_full_blocks(2, &ch(&hs2));
        m.free_request(2);

        // Re-acquire with the grown chain: the 4 existing pins are kept
        // and only the 2-block delta is newly pinned — and the fast path
        // never materializes a hash vector (chain-op counters pin it).
        let grown = ch(&hs).extend(&hs2[4..]);
        crate::kvcache::chain::take_chain_ops();
        assert_eq!(m.acquire_lease(7, &grown), 6);
        let (_appends, full_copies) = crate::kvcache::chain::take_chain_ops();
        assert_eq!(full_copies, 0, "lease re-acquire is zero-copy");
        assert_eq!(m.stats().lease_blocks_pinned, 6, "delta-only accounting");
        assert_eq!(m.lease_size(7), 6);
        assert_eq!(m.num_leases(), 1);
        assert_eq!(m.routing_summary().tracked_prefix(7), Some((6, 6)));

        // Idempotent re-acquire: nothing new to pin.
        assert_eq!(m.acquire_lease(7, &grown), 6);
        assert_eq!(m.stats().lease_blocks_pinned, 6);
        m.check_invariants().unwrap();

        // A diverged chain (session rewrite) falls back to a full re-pin.
        let t3: Vec<u32> = (0..64).map(|i| 50_000 + i).collect();
        let hs3 = block_hashes(&t3, 16, &HashContext::base());
        m.start_request(3, &ch(&hs3), 64);
        assert!(m.ensure_capacity(3, 64));
        m.commit_full_blocks(3, &ch(&hs3));
        m.free_request(3);
        assert_eq!(m.acquire_lease(7, &ch(&hs3)), 4);
        assert_eq!(m.lease_size(7), 4);
        assert_eq!(m.routing_summary().tracked_prefix(7), Some((4, 4)));
        m.check_invariants().unwrap();
    }

    #[test]
    fn property_incremental_affinity_matches_recompute_under_churn() {
        // ISSUE 6 property (b): the incrementally-maintained affinity of
        // every tracked (leased) chain must equal a fresh recompute from
        // the sketch under arbitrary commit / evict / lease-break churn.
        // `check_invariants` → `check_tracked` verifies the slot-level
        // invariant; the explicit comparison below pins the public-API
        // statement (`tracked_prefix` == `matching_prefix`).
        use crate::util::prop;
        use crate::{prop_assert, prop_assert_eq};
        prop::check("lease-affinity-incremental", 20, |rng, _| {
            let mut m = KvCacheManager::new(rng.range(8, 40) as u32, 16, true);
            // lease key -> token stream backing its conversation chain
            let mut convs: Vec<(u64, Vec<u32>)> = vec![];
            let mut next_lease = 0u64;
            let mut next_key = 10_000u64;
            let mut run_turn = |m: &mut KvCacheManager, t: &[u32], key: u64| {
                let hs = block_hashes(t, 16, &HashContext::base());
                let c = ChainRef::from_hashes(&hs);
                m.start_request(key, &c, t.len());
                if m.ensure_capacity(key, t.len()) {
                    m.commit_full_blocks(key, &c);
                }
                m.free_request(key);
                hs
            };
            for _ in 0..120 {
                match rng.next_below(6) {
                    0 | 1 => {
                        // Background traffic: churns the pool, evicting
                        // unpinned blocks out from under tracked chains.
                        let n = rng.range(1, 5) as usize * 16;
                        let t: Vec<u32> =
                            (0..n).map(|_| rng.next_below(96) as u32).collect();
                        run_turn(&mut m, &t, next_key);
                        next_key += 1;
                    }
                    2 => {
                        // New conversation: run its first turn, then lease.
                        next_lease += 1;
                        let n = rng.range(1, 4) as usize * 16;
                        let t: Vec<u32> =
                            (0..n).map(|_| rng.next_below(96) as u32).collect();
                        let hs = run_turn(&mut m, &t, next_key);
                        next_key += 1;
                        m.acquire_lease(next_lease, &ch(&hs));
                        convs.push((next_lease, t));
                    }
                    3 => {
                        // Delta turn on an existing conversation.
                        if !convs.is_empty() {
                            let i = rng.next_below(convs.len() as u64) as usize;
                            let add = rng.range(1, 3) as usize * 16;
                            let mut t = convs[i].1.clone();
                            t.extend((0..add).map(|_| rng.next_below(96) as u32));
                            let lease = convs[i].0;
                            let hs = run_turn(&mut m, &t, next_key);
                            next_key += 1;
                            m.acquire_lease(lease, &ch(&hs));
                            convs[i].1 = t;
                        }
                    }
                    4 => {
                        if !convs.is_empty() {
                            let i = rng.next_below(convs.len() as u64) as usize;
                            let (lease, _) = convs.swap_remove(i);
                            m.release_lease(lease);
                        }
                    }
                    _ => {}
                }
                m.check_invariants()?;
                for (lease, t) in &convs {
                    // Leases broken by pressure reclaim are untracked.
                    if let Some((matched, len)) =
                        m.routing_summary().tracked_prefix(*lease)
                    {
                        let hs = block_hashes(t, 16, &HashContext::base());
                        prop_assert_eq!(len, hs.len());
                        prop_assert_eq!(
                            matched,
                            m.routing_summary().matching_prefix(&hs)
                        );
                    }
                }
            }
            for (lease, _) in &convs {
                m.release_lease(*lease);
            }
            m.check_invariants()?;
            prop_assert!(m.num_leases() == 0, "leases linger");
            Ok(())
        });
    }

    #[test]
    fn fork_shared_lease_refcounts_drain_across_replicas() {
        // Fork leak pin (ISSUE 8 satellite): K children forked from one
        // parent all lease the SAME interned chain — on the home replica
        // as pure refcount pins, and on a second replica via the
        // migration splice. Releasing every lease in seeded-random order
        // across both managers must drain both leased gauges to exactly
        // zero, and once the last handle drops the arena must hold no
        // node of the chain: the shared prefix is refcounted, never
        // copied, and never leaked. Hashes carry a unique tag byte so
        // concurrently-running tests can't perturb the arena count.
        fn tagged(x: u64) -> BlockHash {
            BlockHash(0xB8u64 << 56 | x)
        }
        fn count_tag() -> usize {
            crate::kvcache::chain::arena_count_nodes(|h| h.0 >> 56 == 0xB8)
        }
        let live0 = count_tag();
        let hs: Vec<BlockHash> = (0..6u64).map(tagged).collect();
        {
            let chain = ch(&hs);
            let mut a = mgr(8); // home replica
            let mut b = mgr(8); // migration destination
            // Commit the prefix on the home replica via the normal
            // request flow (the parent's prefill).
            a.start_request(1, &chain, 96);
            assert!(a.ensure_capacity(1, 96));
            a.commit_full_blocks(1, &chain);
            a.free_request(1);
            let free_a = a.num_free_blocks();
            // Parent + 3 same-replica children: each lease pins the same
            // six physical blocks — zero new allocations (acceptance (b)
            // at the pool level).
            let keys_a = [100u64, 101, 102, 103];
            for &k in &keys_a {
                assert_eq!(a.acquire_lease(k, &chain), 6);
            }
            assert_eq!(a.num_free_blocks(), free_a, "fork allocated blocks");
            assert_eq!(a.leased_blocks(), 24, "per-lease gauge counts each pin");
            assert_eq!(a.leased_distinct_blocks(), 6, "one physical copy");
            // A fourth child lands cross-replica: the migration splice
            // installs the same chain cold on B.
            assert_eq!(b.install_migrated_lease(200, &chain), 6);
            assert_eq!(b.leased_blocks(), 6);
            assert_eq!(b.routing_summary().matching_prefix(&hs), 6);
            // Release all five leases in seeded-random order, interleaved
            // across the two replicas.
            let mut work: Vec<(usize, u64)> =
                keys_a.iter().map(|&k| (0, k)).collect();
            work.push((1, 200));
            crate::util::rng::Rng::new(0xB8).shuffle(&mut work);
            for (replica, key) in work {
                let m = if replica == 0 { &mut a } else { &mut b };
                m.release_lease(key);
                m.check_invariants().unwrap();
            }
            assert_eq!(a.leased_blocks(), 0, "home pins linger");
            assert_eq!(b.leased_blocks(), 0, "migrated pins linger");
            assert_eq!(a.num_leases(), 0);
            assert_eq!(b.num_leases(), 0);
            // Releasing unpins without evicting: both replicas still
            // serve the prefix from cache.
            assert_eq!(a.routing_summary().matching_prefix(&hs), 6);
            assert_eq!(b.routing_summary().matching_prefix(&hs), 6);
        }
        // Managers and the local handle dropped: every refcount the fork
        // fan-out took has been given back.
        assert_eq!(count_tag(), live0, "fork-shared chain leaked arena nodes");
    }

    #[test]
    fn migrated_lease_install_is_idempotent_and_degrades_at_exhaustion() {
        // The destination-side splice: re-installing the same chain under
        // the same key replaces (not stacks) the lease; a full pool
        // installs only the prefix that fits; a caching-disabled replica
        // declines outright (the cluster then falls back to recompute).
        let mut m = mgr(4);
        let t = toks(64);
        let hs = block_hashes(&t, 16, &HashContext::base());
        assert_eq!(m.install_migrated_lease(7, &ch(&hs)), 4);
        assert_eq!(m.install_migrated_lease(7, &ch(&hs)), 4, "idempotent");
        assert_eq!(m.num_leases(), 1);
        assert_eq!(m.leased_blocks(), 4);
        m.check_invariants().unwrap();
        m.release_lease(7);
        // Exhaustion: a second, disjoint chain finds no free blocks left
        // to overwrite while the first is pinned... so only dedup'd
        // prefixes install.
        assert_eq!(m.install_migrated_lease(8, &ch(&hs)), 4);
        let t2: Vec<u32> = (0..64).map(|i| 30_000 + i).collect();
        let hs2 = block_hashes(&t2, 16, &HashContext::base());
        assert_eq!(m.install_migrated_lease(9, &ch(&hs2)), 0, "pool exhausted");
        assert_eq!(m.num_leases(), 1, "no phantom lease registered");
        m.release_lease(8);
        m.check_invariants().unwrap();
        // Caching disabled: nothing to splice into.
        let mut off = KvCacheManager::new(8, 16, false);
        assert_eq!(off.install_migrated_lease(1, &ch(&hs)), 0);
        assert_eq!(off.num_leases(), 0);
        off.check_invariants().unwrap();
    }

    #[test]
    fn property_random_workload_invariants() {
        use crate::util::prop;
        prop::check("manager-random", 25, |rng, _| {
            let mut m = KvCacheManager::new(rng.range(4, 32) as u32, 16, true);
            let mut live: Vec<(u64, Vec<BlockHash>, usize)> = vec![];
            let mut next_key = 0u64;
            for _ in 0..120 {
                match rng.next_below(3) {
                    0 => {
                        let n = rng.range(1, 6) as usize * 16;
                        let t: Vec<u32> =
                            (0..n).map(|_| rng.next_below(64) as u32).collect();
                        let hs = block_hashes(&t, 16, &HashContext::base());
                        let key = next_key;
                        next_key += 1;
                        m.start_request(key, &ch(&hs), n);
                        if m.ensure_capacity(key, n) {
                            m.commit_full_blocks(key, &ch(&hs));
                            live.push((key, hs, n));
                        } else {
                            m.free_request(key);
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let i = rng.next_below(live.len() as u64) as usize;
                            let (key, _, _) = live.swap_remove(i);
                            m.free_request(key);
                        }
                    }
                    _ => m.check_invariants()?,
                }
            }
            for (key, _, _) in live {
                m.free_request(key);
            }
            m.check_invariants()?;
            if m.num_free_blocks() != m.num_total_blocks() {
                return Err("blocks leaked".into());
            }
            Ok(())
        });
    }
}
