//! Interned block-hash chains: an append-only, refcounted, prefix-sharing
//! arena behind cheap [`ChainRef`] handles.
//!
//! Before this module, every layer that carried a conversation's block-hash
//! chain — sessions, leases, tracked routing chains, submit paths — held its
//! own `Vec<BlockHash>` and cloned it at each boundary, so one delta turn
//! cost O(conversation) memcpy several times over. The arena stores each
//! chain as a parent-linked node per block, interned by `(parent, hash)`:
//!
//! - extending a chain by a delta turn is O(delta) node appends,
//! - sharing a chain (handing it to routing, the engine, a lease) is O(1)
//!   — a refcount bump,
//! - two sessions with a common prefix share the prefix's nodes,
//! - an aLoRA `append:false` branch is just a second child of the same
//!   parent node — the divergent evaluation chain coexists with the
//!   conversation chain at the cost of its delta only.
//!
//! Interning gives identity ⟺ equality: two `ChainRef`s with the same head
//! node index hold the same hash sequence, so "is chain B an extension of
//! chain A" is an O(delta) walk up B comparing a node *index*, never a
//! hash-by-hash scan. That identity check is what lets leases and tracked
//! routing chains verify the common delta-turn fast path without
//! materializing anything.
//!
//! Refcount invariant: a node's count equals the number of `ChainRef`
//! handles whose head is that node plus the number of child nodes linking
//! it as parent. A node is freed (and its `(parent, hash)` interning entry
//! removed) when the count reaches zero, cascading up the parent link
//! iteratively — never recursively, so million-block chains can't overflow
//! the stack on drop.
//!
//! The arena is a process-wide singleton behind a plain mutex. Every
//! operation holds the lock for O(delta) pointer work; node *indices*
//! never leave this module's arithmetic (only `BlockHash` values flow
//! out), so cross-thread allocation order can't perturb placement or
//! hashing — the determinism bar survives a concurrent server.
//!
//! Instrumentation mirrors `prefix::take_hash_ops`: thread-local counters
//! for node appends and full-chain materializations let acceptance tests
//! pin "O(delta) appends, zero full-chain copies per delta turn".

use std::cell::Cell;
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::util::fxmap::FxHashMap;

use super::block::BlockHash;

/// Sentinel parent index for chain roots.
const NIL: u32 = u32::MAX;

thread_local! {
    /// Arena node appends on this thread since the last [`take_chain_ops`].
    static CHAIN_APPENDS: Cell<u64> = const { Cell::new(0) };
    /// Full-chain materializations (an O(len) `Vec<BlockHash>` copy) on
    /// this thread since the last [`take_chain_ops`].
    static CHAIN_FULL_COPIES: Cell<u64> = const { Cell::new(0) };
}

/// Drain this thread's chain-op counters: `(node_appends, full_copies)`.
/// The delta-turn acceptance test pins appends = O(delta) and
/// full_copies = 0 — the zero-copy statement of ISSUE 7.
pub fn take_chain_ops() -> (u64, u64) {
    (
        CHAIN_APPENDS.with(|c| c.replace(0)),
        CHAIN_FULL_COPIES.with(|c| c.replace(0)),
    )
}

#[derive(Debug)]
struct Node {
    hash: BlockHash,
    parent: u32,
    /// Handles with this head + child nodes linking this as parent.
    refs: u32,
}

#[derive(Default)]
struct ChainArena {
    nodes: Vec<Node>,
    free: Vec<u32>,
    /// Interning: `(parent index, hash value)` → node index. `NIL` parent
    /// keys first blocks.
    children: FxHashMap<(u32, u64), u32>,
}

impl ChainArena {
    /// Take one working reference on `idx` (no-op for NIL).
    fn acquire(&mut self, idx: u32) {
        if idx != NIL {
            self.nodes[idx as usize].refs += 1;
        }
    }

    /// Drop one reference on `idx`, freeing up the parent link while
    /// counts hit zero. Iterative: drop of a million-block chain's last
    /// handle walks a loop, not the call stack.
    fn release(&mut self, mut idx: u32) {
        while idx != NIL {
            let n = &mut self.nodes[idx as usize];
            debug_assert!(n.refs > 0, "chain arena release without acquire");
            n.refs -= 1;
            if n.refs > 0 {
                return;
            }
            let parent = n.parent;
            let key = (parent, n.hash.0);
            self.children.remove(&key);
            self.free.push(idx);
            idx = parent;
        }
    }

    /// Append `h` under `cur`, transferring the caller's working ref on
    /// `cur` into the result (interned: the existing child if one exists).
    fn append(&mut self, cur: u32, h: BlockHash) -> u32 {
        CHAIN_APPENDS.with(|c| c.set(c.get() + 1));
        if let Some(&child) = self.children.get(&(cur, h.0)) {
            self.nodes[child as usize].refs += 1;
            // The existing child's parent link already accounts for `cur`;
            // the caller's working ref is surplus. Plain decrement — the
            // child link keeps the count positive, nothing can free here.
            if cur != NIL {
                let n = &mut self.nodes[cur as usize];
                debug_assert!(n.refs > 1);
                n.refs -= 1;
            }
            return child;
        }
        // New node: the caller's working ref on `cur` becomes the child's
        // parent link (no count change on `cur`).
        let idx = match self.free.pop() {
            Some(i) => {
                self.nodes[i as usize] = Node { hash: h, parent: cur, refs: 1 };
                i
            }
            None => {
                assert!(self.nodes.len() < NIL as usize, "chain arena full");
                self.nodes.push(Node { hash: h, parent: cur, refs: 1 });
                (self.nodes.len() - 1) as u32
            }
        };
        self.children.insert((cur, h.0), idx);
        idx
    }

    /// Node index at chain position `pos` for a chain with head `head` of
    /// length `len` (walks `len - 1 - pos` parent links).
    fn at(&self, head: u32, len: usize, pos: usize) -> u32 {
        debug_assert!(pos < len);
        let mut idx = head;
        for _ in pos..len - 1 {
            idx = self.nodes[idx as usize].parent;
        }
        idx
    }

    fn live_nodes(&self) -> usize {
        self.nodes.len() - self.free.len()
    }
}

fn arena() -> MutexGuard<'static, ChainArena> {
    static ARENA: OnceLock<Mutex<ChainArena>> = OnceLock::new();
    ARENA
        .get_or_init(|| Mutex::new(ChainArena::default()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Live node count in the process-wide arena (diagnostics; other threads
/// allocate concurrently, so treat as a gauge, not an exact ledger).
pub fn arena_live_nodes() -> usize {
    arena().live_nodes()
}

/// Live nodes whose hash satisfies `pred` — race-free leak checks in
/// tests: tag a test's hashes with a unique marker and count only those,
/// so concurrently-running tests can't perturb the assertion. O(arena),
/// test-only.
#[doc(hidden)]
pub fn arena_count_nodes(pred: impl Fn(BlockHash) -> bool) -> usize {
    let a = arena();
    let free: crate::util::fxmap::FxHashSet<u32> = a.free.iter().copied().collect();
    a.nodes
        .iter()
        .enumerate()
        .filter(|(i, n)| !free.contains(&(*i as u32)) && pred(n.hash))
        .count()
}

/// A refcounted handle on an interned block-hash chain. Clone is O(1)
/// (refcount bump), drop releases the chain's nodes back to the arena,
/// and equality is node identity — which, by interning, is exactly
/// hash-sequence equality.
pub struct ChainRef {
    head: u32,
    len: u32,
}

impl ChainRef {
    /// The empty chain (no arena interaction).
    pub fn empty() -> Self {
        ChainRef { head: NIL, len: 0 }
    }

    /// Intern a full hash slice (the cold path: first turns, rehash
    /// fallbacks, evacuation requeues). O(len) appends.
    pub fn from_hashes(hashes: &[BlockHash]) -> Self {
        Self::empty().extend(hashes)
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// A new chain = self + `delta`, sharing every node of `self`.
    /// O(delta) appends; `self` is untouched (an aLoRA `append:false`
    /// branch extends the same parent a second time and simply interns a
    /// second child).
    pub fn extend(&self, delta: &[BlockHash]) -> ChainRef {
        if delta.is_empty() {
            return self.clone();
        }
        let mut a = arena();
        let mut cur = self.head;
        a.acquire(cur);
        for &h in delta {
            cur = a.append(cur, h);
        }
        ChainRef { head: cur, len: self.len + delta.len() as u32 }
    }

    /// Last block hash, O(1).
    pub fn last(&self) -> Option<BlockHash> {
        if self.head == NIL {
            return None;
        }
        let a = arena();
        Some(a.nodes[self.head as usize].hash)
    }

    /// Hash at position `pos` — O(len − pos) parent walk, so cheap near
    /// the tail.
    pub fn hash_at(&self, pos: usize) -> BlockHash {
        assert!(pos < self.len());
        let a = arena();
        let idx = a.at(self.head, self.len(), pos);
        a.nodes[idx as usize].hash
    }

    /// Is `base` a prefix of `self`? O(self.len − base.len) walk up to the
    /// node at `base`'s length, then a single node-identity comparison —
    /// interning makes index equality sufficient AND necessary.
    pub fn is_extension_of(&self, base: &ChainRef) -> bool {
        if base.len == 0 {
            return true;
        }
        if base.len > self.len {
            return false;
        }
        let a = arena();
        a.at(self.head, self.len(), base.len() - 1) == base.head
    }

    /// The length-`k` prefix as its own handle. O(len − k) walk — cheap
    /// when `k` is near the tail (the lease-pinning use).
    pub fn prefix(&self, k: usize) -> ChainRef {
        assert!(k <= self.len());
        if k == 0 {
            return ChainRef::empty();
        }
        let mut a = arena();
        let idx = a.at(self.head, self.len(), k - 1);
        a.acquire(idx);
        ChainRef { head: idx, len: k as u32 }
    }

    /// Hashes at positions `start..end`, forward order. O(len − start)
    /// walk + O(end − start) copy — the delta-suffix access pattern.
    /// A `start == 0` call over a non-empty chain is a full-chain copy
    /// and is counted as one (see [`take_chain_ops`]).
    pub fn range(&self, start: usize, end: usize) -> Vec<BlockHash> {
        assert!(start <= end && end <= self.len());
        if start == end {
            return Vec::new();
        }
        if start == 0 {
            CHAIN_FULL_COPIES.with(|c| c.set(c.get() + 1));
        }
        let a = arena();
        let mut out = vec![BlockHash(0); end - start];
        let mut idx = a.at(self.head, self.len(), end - 1);
        for slot in out.iter_mut().rev() {
            let n = &a.nodes[idx as usize];
            *slot = n.hash;
            idx = n.parent;
        }
        out
    }

    /// Hashes from position `start` to the tail.
    pub fn suffix(&self, start: usize) -> Vec<BlockHash> {
        self.range(start, self.len())
    }

    /// Full materialization — an O(len) copy, counted. Kept off every
    /// delta-turn path; used by cold routing scans, divergence rebuilds
    /// and equivalence tests.
    pub fn hashes(&self) -> Vec<BlockHash> {
        self.range(0, self.len())
    }

    /// Visit hashes from position `start` forward, stopping when `f`
    /// returns false. Allocates only an index scratch (no hash copy) —
    /// admission's walk-until-first-miss without materializing.
    ///
    /// `f` runs under the arena lock: it must not create, clone, or drop
    /// `ChainRef`s (re-entrant lock).
    pub fn visit_from(&self, start: usize, mut f: impl FnMut(BlockHash) -> bool) {
        if start >= self.len() {
            return;
        }
        let a = arena();
        let mut stack = Vec::with_capacity(self.len() - start);
        let mut idx = self.head;
        for _ in start..self.len() {
            stack.push(idx);
            idx = a.nodes[idx as usize].parent;
        }
        for idx in stack.into_iter().rev() {
            if !f(a.nodes[idx as usize].hash) {
                return;
            }
        }
    }
}

impl Clone for ChainRef {
    fn clone(&self) -> Self {
        if self.head != NIL {
            arena().acquire(self.head);
        }
        ChainRef { head: self.head, len: self.len }
    }
}

impl Drop for ChainRef {
    fn drop(&mut self) {
        if self.head != NIL {
            arena().release(self.head);
        }
    }
}

impl PartialEq for ChainRef {
    /// Node identity — by interning, exactly hash-sequence equality.
    fn eq(&self, other: &Self) -> bool {
        self.head == other.head && self.len == other.len
    }
}

impl Eq for ChainRef {}

impl std::fmt::Debug for ChainRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ChainRef(len={}, head={})", self.len, self.head as i64)
    }
}

impl Default for ChainRef {
    fn default() -> Self {
        ChainRef::empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h(x: u64) -> BlockHash {
        BlockHash(x.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }

    fn hs(xs: &[u64]) -> Vec<BlockHash> {
        xs.iter().map(|&x| h(x)).collect()
    }

    #[test]
    fn roundtrip_and_equality_by_interning() {
        let a = ChainRef::from_hashes(&hs(&[1, 2, 3]));
        assert_eq!(a.len(), 3);
        assert_eq!(a.hashes(), hs(&[1, 2, 3]));
        assert_eq!(a.last(), Some(h(3)));
        assert_eq!(a.hash_at(0), h(1));
        // Same sequence interns to the same nodes: identity == equality.
        let b = ChainRef::from_hashes(&hs(&[1, 2, 3]));
        assert_eq!(a, b);
        let c = ChainRef::from_hashes(&hs(&[1, 2, 4]));
        assert_ne!(a, c);
        assert!(ChainRef::empty().is_empty());
        assert_eq!(ChainRef::empty(), ChainRef::empty());
    }

    /// Tagged hash: high byte marks the owning test so leak counts are
    /// immune to concurrently-running tests touching the shared arena.
    fn tagged(tag: u8, x: u64) -> BlockHash {
        BlockHash((tag as u64) << 56 | (x & 0x00FF_FFFF_FFFF_FFFF))
    }

    fn count_tag(tag: u8) -> usize {
        arena_count_nodes(|h| h.0 >> 56 == tag as u64)
    }

    #[test]
    fn extend_shares_prefix_and_branches() {
        let t = |x| tagged(0xA1, x);
        let base = ChainRef::from_hashes(&[t(1), t(2)]);
        let live0 = count_tag(0xA1);
        let turn = base.extend(&[t(3), t(4)]);
        // Only the delta allocated.
        assert_eq!(count_tag(0xA1), live0 + 2);
        // Re-interning the same sequence allocates nothing new.
        let turn_again = base.extend(&[t(3), t(4)]);
        assert_eq!(turn, turn_again);
        assert_eq!(count_tag(0xA1), live0 + 2);
        let hs = |xs: &[u64]| xs.iter().map(|&x| t(x)).collect::<Vec<_>>();
        assert!(turn.is_extension_of(&base));
        assert!(!base.is_extension_of(&turn));
        assert!(turn.is_extension_of(&turn));
        assert!(turn.is_extension_of(&ChainRef::empty()));
        // aLoRA append:false branch: second child of the same parent.
        let branch = base.extend(&hs(&[9]));
        assert!(branch.is_extension_of(&base));
        assert!(!branch.is_extension_of(&turn));
        assert_eq!(branch.hashes(), hs(&[1, 2, 9]));
        assert_eq!(turn.hashes(), hs(&[1, 2, 3, 4]));
        // A diverged chain is not an extension even at equal length.
        let other = ChainRef::from_hashes(&hs(&[1, 7]));
        assert!(!turn.is_extension_of(&other));
    }

    #[test]
    fn drop_frees_unshared_tail_only() {
        let t = |x| tagged(0xA2, x);
        let base = ChainRef::from_hashes(&[t(10), t(11)]);
        let live0 = count_tag(0xA2);
        {
            let tail = base.extend(&[t(12), t(13)]);
            assert_eq!(count_tag(0xA2), live0 + 2);
            let t2 = tail.clone(); // O(1) share
            drop(tail);
            assert_eq!(count_tag(0xA2), live0 + 2, "clone keeps the tail");
            drop(t2);
        }
        assert_eq!(count_tag(0xA2), live0, "tail freed, base intact");
        assert_eq!(base.hashes(), vec![t(10), t(11)]);
        // Re-extend re-interns cleanly after the free.
        let again = base.extend(&[t(12)]);
        assert_eq!(again.hashes(), vec![t(10), t(11), t(12)]);
    }

    #[test]
    fn prefix_suffix_range() {
        let c = ChainRef::from_hashes(&hs(&[1, 2, 3, 4, 5]));
        let p = c.prefix(3);
        assert_eq!(p.hashes(), hs(&[1, 2, 3]));
        assert_eq!(p, ChainRef::from_hashes(&hs(&[1, 2, 3])));
        assert!(c.is_extension_of(&p));
        assert_eq!(c.prefix(0), ChainRef::empty());
        assert_eq!(c.prefix(5), c);
        assert_eq!(c.suffix(3), hs(&[4, 5]));
        assert_eq!(c.suffix(5), vec![]);
        assert_eq!(c.range(1, 4), hs(&[2, 3, 4]));
        let mut seen = Vec::new();
        c.visit_from(2, |x| {
            seen.push(x);
            seen.len() < 2 // early exit after two
        });
        assert_eq!(seen, hs(&[3, 4]));
    }

    #[test]
    fn op_counters_pin_delta_work() {
        let base = ChainRef::from_hashes(&hs(&[1, 2, 3, 4]));
        take_chain_ops();
        let t = base.extend(&hs(&[5]));
        let _share = t.clone();
        let _tail = t.suffix(4);
        assert_eq!(t.last(), Some(h(5)));
        let (appends, copies) = take_chain_ops();
        assert_eq!(appends, 1, "one delta block appended");
        assert_eq!(copies, 0, "no full-chain copy on the delta path");
        let _all = t.hashes();
        let (_, copies) = take_chain_ops();
        assert_eq!(copies, 1, "full materialization is counted");
    }

    #[test]
    fn property_arena_matches_vec_semantics() {
        // Random grow/branch/drop churn: every live ChainRef's
        // materialization equals the Vec<BlockHash> a copy-based
        // implementation would hold, and balanced drops leak no nodes.
        use crate::util::prop;
        prop::check("chain-arena-vec-equivalence", 20, |rng, _| {
            {
                let mut model: Vec<(ChainRef, Vec<BlockHash>)> =
                    vec![(ChainRef::empty(), vec![])];
                for _ in 0..200 {
                    match rng.next_below(5) {
                        0 | 1 => {
                            // Extend a random chain by a random delta.
                            let i = rng.next_below(model.len() as u64) as usize;
                            let k = rng.range(1, 4) as usize;
                            let delta: Vec<BlockHash> =
                                (0..k).map(|_| h(rng.next_below(32))).collect();
                            let c = model[i].0.extend(&delta);
                            let mut v = model[i].1.clone();
                            v.extend_from_slice(&delta);
                            model.push((c, v));
                        }
                        2 => {
                            // Clone (share).
                            let i = rng.next_below(model.len() as u64) as usize;
                            let pair = (model[i].0.clone(), model[i].1.clone());
                            model.push(pair);
                        }
                        3 => {
                            // Prefix.
                            let i = rng.next_below(model.len() as u64) as usize;
                            let k =
                                rng.next_below(model[i].1.len() as u64 + 1) as usize;
                            let c = model[i].0.prefix(k);
                            let v = model[i].1[..k].to_vec();
                            model.push((c, v));
                        }
                        _ => {
                            if model.len() > 1 {
                                let i = rng.next_below(model.len() as u64) as usize;
                                model.swap_remove(i);
                            }
                        }
                    }
                    for (c, v) in &model {
                        if &c.hashes() != v {
                            return Err("arena chain diverged from Vec model".into());
                        }
                        if c.len() != v.len() {
                            return Err("length drifted".into());
                        }
                        if c.last() != v.last().copied() {
                            return Err("last drifted".into());
                        }
                    }
                    // Cross-chain extension checks match Vec prefix tests.
                    let a = &model[rng.next_below(model.len() as u64) as usize];
                    let b = &model[rng.next_below(model.len() as u64) as usize];
                    let is_prefix = a.1.len() <= b.1.len()
                        && b.1[..a.1.len()] == a.1[..];
                    if b.0.is_extension_of(&a.0) != is_prefix {
                        return Err("is_extension_of diverged from Vec model".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn balanced_drop_leaks_nothing() {
        // Leak pin over tag-unique hashes: count only this test's nodes,
        // immune to concurrent tests churning the shared arena.
        let probe: Vec<BlockHash> = (0..64u64).map(|i| tagged(0xA3, i)).collect();
        assert_eq!(count_tag(0xA3), 0);
        {
            let base = ChainRef::from_hashes(&probe[..32]);
            let t1 = base.extend(&probe[32..48]);
            let t2 = base.extend(&probe[48..]);
            let _c1 = t1.clone();
            let _p = t2.prefix(40);
            assert_eq!(count_tag(0xA3), 64);
        }
        assert_eq!(count_tag(0xA3), 0, "balanced drops leak no nodes");
    }
}
