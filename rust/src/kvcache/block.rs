//! Physical KV block bookkeeping (PagedAttention-style, Kwon et al. 2023).
//!
//! A [`BlockPool`] owns `num_blocks` fixed-size physical blocks. Freed
//! blocks keep their contents and hash and sit in an LRU free list — any
//! later request whose chained hash matches may resurrect them (vLLM's
//! automatic prefix caching, paper §3). Eviction happens lazily when a
//! fresh allocation pops the LRU end.

use crate::memory::MemoryBudget;
use crate::util::fxmap::FxHashMap;

use super::summary::HashSummary;

/// Physical block index into the (simulated or real) KV arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

/// Chained content hash of a full block (kvcache::hash).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockHash(pub u64);

#[derive(Debug, Clone)]
struct BlockMeta {
    ref_count: u32,
    /// Content hash once the block is full and committed; None for
    /// partially-filled tail blocks (never shareable — Figure 3: the
    /// activation tokens are not cached while they don't fill a block).
    hash: Option<BlockHash>,
    /// Free-list links (intrusive doubly-linked list, usize::MAX = none).
    prev: usize,
    next: usize,
    in_free_list: bool,
}

/// Counters exported through the metrics registry.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PoolStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub allocations: u64,
}

/// Fixed-capacity pool with hash lookup + LRU reuse of freed blocks.
#[derive(Debug)]
pub struct BlockPool {
    meta: Vec<BlockMeta>,
    /// hash -> block holding those contents (in use or free-but-cached).
    by_hash: FxHashMap<BlockHash, BlockId>,
    /// LRU list head/tail over FREE blocks (head = oldest = evict first).
    free_head: usize,
    free_tail: usize,
    free_count: usize,
    stats: PoolStats,
    /// Routable sketch of the committed hashes, maintained on the same
    /// commit/evict events that update `by_hash` (cluster routing reads it
    /// through `KvCacheManager::routing_summary`).
    summary: HashSummary,
    /// Unified device-memory ledger: KV pages and resident adapter weights
    /// draw from the same free list; the budget records the adapter share.
    budget: MemoryBudget,
}

const NONE: usize = usize::MAX;

impl BlockPool {
    pub fn new(num_blocks: u32) -> Self {
        assert!(num_blocks > 0, "empty block pool");
        let mut pool = BlockPool {
            meta: (0..num_blocks)
                .map(|_| BlockMeta {
                    ref_count: 0,
                    hash: None,
                    prev: NONE,
                    next: NONE,
                    in_free_list: false,
                })
                .collect(),
            by_hash: FxHashMap::default(),
            free_head: NONE,
            free_tail: NONE,
            free_count: 0,
            stats: PoolStats::default(),
            summary: HashSummary::new(),
            budget: MemoryBudget::new(num_blocks as usize),
        };
        // All blocks start free (and hashless).
        for i in 0..num_blocks {
            pool.push_free(BlockId(i));
        }
        pool
    }

    pub fn num_blocks(&self) -> u32 {
        self.meta.len() as u32
    }

    pub fn num_free(&self) -> u32 {
        self.free_count as u32
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    pub fn ref_count(&self, b: BlockId) -> u32 {
        self.meta[b.0 as usize].ref_count
    }

    pub fn hash_of(&self, b: BlockId) -> Option<BlockHash> {
        self.meta[b.0 as usize].hash
    }

    /// The routable committed-hash summary (see [`HashSummary`]).
    pub fn routing_summary(&self) -> &HashSummary {
        &self.summary
    }

    /// Register a lease's chain with the summary for incremental affinity
    /// maintenance (fed by the same commit/evict events as the sketch).
    pub fn track_chain(&mut self, key: u64, chain: &super::chain::ChainRef) {
        self.summary.track(key, chain);
    }

    /// Forget a lease's tracked chain (lease released or broken).
    pub fn untrack_chain(&mut self, key: u64) {
        self.summary.untrack(key);
    }

    /// The unified memory ledger (KV vs adapter-weight split).
    pub fn budget(&self) -> &MemoryBudget {
        &self.budget
    }

    /// Mutable ledger access for the host tier (DESIGN.md §20): host
    /// blocks are modeled capacity with no physical `BlockId`s, so the
    /// residency layer charges them directly — the pool's free list and
    /// device invariants are never involved.
    pub fn budget_mut(&mut self) -> &mut MemoryBudget {
        &mut self.budget
    }

    // -- free-list plumbing --------------------------------------------------

    fn push_free(&mut self, b: BlockId) {
        let i = b.0 as usize;
        debug_assert!(!self.meta[i].in_free_list);
        self.meta[i].prev = self.free_tail;
        self.meta[i].next = NONE;
        if self.free_tail != NONE {
            self.meta[self.free_tail].next = i;
        } else {
            self.free_head = i;
        }
        self.free_tail = i;
        self.meta[i].in_free_list = true;
        self.free_count += 1;
    }

    fn unlink_free(&mut self, b: BlockId) {
        let i = b.0 as usize;
        debug_assert!(self.meta[i].in_free_list);
        let (p, n) = (self.meta[i].prev, self.meta[i].next);
        if p != NONE {
            self.meta[p].next = n;
        } else {
            self.free_head = n;
        }
        if n != NONE {
            self.meta[n].prev = p;
        } else {
            self.free_tail = p;
        }
        self.meta[i].prev = NONE;
        self.meta[i].next = NONE;
        self.meta[i].in_free_list = false;
        self.free_count -= 1;
    }

    // -- public API ------------------------------------------------------------

    /// Cache lookup: if a block with `hash` exists (in use or free), bump
    /// its ref count (resurrecting it from the free list if needed) and
    /// return it. Counts a hit/miss.
    pub fn lookup(&mut self, hash: BlockHash) -> Option<BlockId> {
        match self.by_hash.get(&hash).copied() {
            Some(b) => {
                let i = b.0 as usize;
                if self.meta[i].in_free_list {
                    self.unlink_free(b);
                }
                self.meta[i].ref_count += 1;
                self.stats.hits += 1;
                Some(b)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Peek-only variant (no refcount change, no stats) — used by the
    /// scheduler to size a request's cached prefix before committing.
    pub fn contains(&self, hash: BlockHash) -> bool {
        self.by_hash.contains_key(&hash)
    }

    /// Take a reference on the cached block holding `hash` without
    /// counting a hit or miss — the session prefix-lease path. A lease is
    /// *retention* between turns, not an admission, so it must not skew
    /// the hit-rate counters the figures read. Resurrects free-list
    /// blocks exactly like [`BlockPool::lookup`].
    pub fn pin(&mut self, hash: BlockHash) -> Option<BlockId> {
        let b = self.by_hash.get(&hash).copied()?;
        let i = b.0 as usize;
        if self.meta[i].in_free_list {
            self.unlink_free(b);
        }
        self.meta[i].ref_count += 1;
        Some(b)
    }

    /// Allocate a fresh block: pops the LRU free block, evicting whatever
    /// hashed contents it still carried. Returns None when the pool is
    /// exhausted (all blocks referenced) — the scheduler then preempts.
    pub fn alloc(&mut self) -> Option<BlockId> {
        if self.free_head == NONE {
            return None;
        }
        let b = BlockId(self.free_head as u32);
        self.unlink_free(b);
        let i = b.0 as usize;
        if let Some(h) = self.meta[i].hash.take() {
            self.by_hash.remove(&h);
            self.summary.remove(h);
            self.stats.evictions += 1;
        }
        self.meta[i].ref_count = 1;
        self.stats.allocations += 1;
        Some(b)
    }

    /// Claim `n` pages for adapter weights from the SAME free list KV
    /// allocations use (S-LoRA unified paging). Atomic: all `n` or none.
    /// Cold cached contents at the LRU end are evicted to make room —
    /// blocks referenced by running requests are never touched, because
    /// only free-list blocks are claimable. Claimed pages carry no hash
    /// (weights are not prefix-cacheable) and are charged to the budget's
    /// adapter side rather than counted as KV allocations.
    pub fn claim_blocks(&mut self, n: usize) -> Option<Vec<BlockId>> {
        if self.free_count < n {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = BlockId(self.free_head as u32);
            self.unlink_free(b);
            let i = b.0 as usize;
            if let Some(h) = self.meta[i].hash.take() {
                // Cached KV content overwritten by weights: a real
                // eviction, counted as such.
                self.by_hash.remove(&h);
                self.summary.remove(h);
                self.stats.evictions += 1;
            }
            self.meta[i].ref_count = 1;
            out.push(b);
        }
        self.budget.charge_adapter(n);
        Some(out)
    }

    /// Return adapter-weight pages claimed via [`BlockPool::claim_blocks`]
    /// to the free list (an adapter eviction). The pages come back
    /// hashless — plain free space for either side of the budget.
    pub fn release_claimed(&mut self, blocks: &[BlockId]) {
        for &b in blocks {
            debug_assert!(
                self.meta[b.0 as usize].hash.is_none(),
                "claimed block {b:?} grew a hash"
            );
            self.free(b);
        }
        self.budget.release_adapter(blocks.len());
    }

    /// Commit a full block's content hash, making it shareable. If another
    /// block already holds this hash, keeps the existing mapping (dedup:
    /// concurrent identical prefills converge on first-committed).
    pub fn commit_hash(&mut self, b: BlockId, hash: BlockHash) {
        let i = b.0 as usize;
        debug_assert!(self.meta[i].ref_count > 0, "committing a free block");
        if self.meta[i].hash.is_some() {
            return; // already committed (e.g. resurrected cached block)
        }
        self.meta[i].hash = Some(hash);
        self.by_hash.entry(hash).or_insert(b);
        self.summary.insert(hash);
    }

    /// Add a reference to an already-referenced block (shared prefix).
    pub fn add_ref(&mut self, b: BlockId) {
        let i = b.0 as usize;
        debug_assert!(self.meta[i].ref_count > 0);
        self.meta[i].ref_count += 1;
    }

    /// Drop a reference; at zero the block joins the free-list tail with
    /// contents + hash retained (reusable until evicted).
    pub fn free(&mut self, b: BlockId) {
        let i = b.0 as usize;
        assert!(self.meta[i].ref_count > 0, "double free of {b:?}");
        self.meta[i].ref_count -= 1;
        if self.meta[i].ref_count == 0 {
            // Hashless partial blocks can never be reused; drop their
            // identity entirely so they're plain free space.
            self.push_free(b);
        }
    }

    /// Drop every cached (committed, unreferenced) hash — the contents of
    /// a failed replica's device memory are gone, so its routable cache
    /// must read as empty rather than attract traffic to blocks that no
    /// longer exist. Each drop emits the same `by_hash`/summary −1 an LRU
    /// eviction would, so the counting sketch stays symmetric — but it is
    /// NOT counted into `stats.evictions`: evictions measure memory
    /// pressure, and a failure wipe is not pressure (same rule as
    /// lease-orphaning vs `leases_reclaimed`). The caller must have freed
    /// every request/lease/claim first (no referenced block may carry a
    /// hash). Returns blocks purged.
    pub fn purge_cached(&mut self) -> usize {
        let mut purged = 0;
        for i in 0..self.meta.len() {
            if let Some(h) = self.meta[i].hash.take() {
                debug_assert_eq!(
                    self.meta[i].ref_count, 0,
                    "purging block {i} still referenced"
                );
                self.by_hash.remove(&h);
                self.summary.remove(h);
                purged += 1;
            }
        }
        purged
    }

    /// Invariant check for tests: free list is consistent, hashes map to
    /// the blocks claiming them.
    #[doc(hidden)]
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = 0;
        let mut i = self.free_head;
        let mut prev = NONE;
        while i != NONE {
            if !self.meta[i].in_free_list {
                return Err(format!("block {i} linked but not marked free"));
            }
            if self.meta[i].ref_count != 0 {
                return Err(format!("free block {i} has refs"));
            }
            if self.meta[i].prev != prev {
                return Err(format!("bad prev link at {i}"));
            }
            prev = i;
            i = self.meta[i].next;
            seen += 1;
            if seen > self.meta.len() {
                return Err("free list cycle".into());
            }
        }
        if seen != self.free_count {
            return Err(format!("free_count {} != walked {seen}", self.free_count));
        }
        for (h, b) in &self.by_hash {
            if self.meta[b.0 as usize].hash != Some(*h) {
                return Err(format!("hash map points at block {b:?} w/o that hash"));
            }
        }
        let hashed = self.meta.iter().filter(|m| m.hash.is_some()).count() as u64;
        if self.summary.committed_blocks() != hashed {
            return Err(format!(
                "routing summary tracks {} committed blocks, pool holds {hashed}",
                self.summary.committed_blocks()
            ));
        }
        self.summary.check_tracked()?;
        // Unified-budget ledger: adapter pages + in-use KV + free == total.
        let in_use = self.meta.len() - self.free_count;
        if self.budget.adapter_blocks() > in_use {
            return Err(format!(
                "budget charges {} adapter blocks but only {in_use} blocks are in use",
                self.budget.adapter_blocks()
            ));
        }
        if self.budget.total_blocks() != self.meta.len() {
            return Err("budget total drifted from pool size".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_until_exhausted() {
        let mut p = BlockPool::new(4);
        let mut got = vec![];
        for _ in 0..4 {
            got.push(p.alloc().unwrap());
        }
        assert!(p.alloc().is_none());
        assert_eq!(p.num_free(), 0);
        for b in got {
            p.free(b);
        }
        assert_eq!(p.num_free(), 4);
        p.check_invariants().unwrap();
    }

    #[test]
    fn freed_hashed_block_is_reusable() {
        let mut p = BlockPool::new(2);
        let b = p.alloc().unwrap();
        p.commit_hash(b, BlockHash(42));
        p.free(b);
        // Hit from free list resurrects with refcount 1.
        let hit = p.lookup(BlockHash(42)).unwrap();
        assert_eq!(hit, b);
        assert_eq!(p.ref_count(b), 1);
        assert_eq!(p.stats().hits, 1);
        p.check_invariants().unwrap();
    }

    #[test]
    fn eviction_is_lru() {
        let mut p = BlockPool::new(2);
        let b0 = p.alloc().unwrap();
        p.commit_hash(b0, BlockHash(1));
        let b1 = p.alloc().unwrap();
        p.commit_hash(b1, BlockHash(2));
        p.free(b0); // freed first -> LRU
        p.free(b1);
        let fresh = p.alloc().unwrap();
        assert_eq!(fresh, b0, "oldest freed block evicted first");
        assert!(!p.contains(BlockHash(1)), "evicted hash gone");
        assert!(p.contains(BlockHash(2)), "newer hash survives");
        assert_eq!(p.stats().evictions, 1);
        p.check_invariants().unwrap();
    }

    #[test]
    fn lookup_refreshes_nothing_but_lookup_order_matters() {
        // Resurrecting then re-freeing moves a block to the LRU tail.
        let mut p = BlockPool::new(3);
        let b0 = p.alloc().unwrap();
        p.commit_hash(b0, BlockHash(10));
        let b1 = p.alloc().unwrap();
        p.commit_hash(b1, BlockHash(11));
        p.free(b0);
        p.free(b1);
        // touch b0 -> now b1 is LRU among hashed
        let r = p.lookup(BlockHash(10)).unwrap();
        p.free(r);
        // pool still has 1 never-used free block (oldest in list initially)
        // drain the untouched one, then the next eviction must hit b1.
        let _fresh = p.alloc().unwrap(); // the never-hashed block
        let evicted = p.alloc().unwrap();
        assert_eq!(evicted, b1);
        assert!(p.contains(BlockHash(10)));
        assert!(!p.contains(BlockHash(11)));
    }

    #[test]
    fn shared_block_not_freed_until_last_ref() {
        let mut p = BlockPool::new(2);
        let b = p.alloc().unwrap();
        p.commit_hash(b, BlockHash(7));
        let again = p.lookup(BlockHash(7)).unwrap();
        assert_eq!(again, b);
        assert_eq!(p.ref_count(b), 2);
        p.free(b);
        assert_eq!(p.num_free(), 1); // still held
        p.free(b);
        assert_eq!(p.num_free(), 2);
        p.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = BlockPool::new(1);
        let b = p.alloc().unwrap();
        p.free(b);
        p.free(b);
    }

    #[test]
    fn routing_summary_follows_commit_and_evict() {
        let mut p = BlockPool::new(2);
        let b0 = p.alloc().unwrap();
        p.commit_hash(b0, BlockHash(11));
        assert!(p.routing_summary().maybe_contains(BlockHash(11)));
        assert_eq!(p.routing_summary().committed_blocks(), 1);
        p.free(b0);
        // Freed-but-cached blocks stay routable until evicted.
        assert!(p.routing_summary().maybe_contains(BlockHash(11)));
        let b1 = p.alloc().unwrap(); // never-hashed block allocated first
        assert_ne!(b1, b0);
        let _evictor = p.alloc().unwrap(); // evicts b0's hash
        assert!(!p.routing_summary().maybe_contains(BlockHash(11)));
        assert_eq!(p.routing_summary().committed_blocks(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn claims_draw_from_the_shared_budget() {
        let mut p = BlockPool::new(4);
        // Warm one cached block, free it (LRU end = oldest free).
        let b = p.alloc().unwrap();
        p.commit_hash(b, BlockHash(9));
        p.free(b);
        assert_eq!(p.budget().adapter_blocks(), 0);
        // Claiming 4 pages must evict the cached content of the freed
        // block (weights overwrite it) and charge the adapter side.
        let claimed = p.claim_blocks(4).unwrap();
        assert_eq!(claimed.len(), 4);
        assert_eq!(p.num_free(), 0);
        assert_eq!(p.budget().adapter_blocks(), 4);
        assert_eq!(p.budget().kv_capacity_blocks(), 0);
        assert!(!p.contains(BlockHash(9)), "weights evicted the cached block");
        assert!(!p.routing_summary().maybe_contains(BlockHash(9)));
        assert_eq!(p.stats().evictions, 1);
        // Exhausted: neither KV nor another adapter can allocate.
        assert!(p.alloc().is_none());
        assert!(p.claim_blocks(1).is_none());
        p.check_invariants().unwrap();
        // Releasing the claim frees KV headroom again.
        p.release_claimed(&claimed);
        assert_eq!(p.num_free(), 4);
        assert_eq!(p.budget().adapter_blocks(), 0);
        assert!(p.alloc().is_some());
        p.check_invariants().unwrap();
    }

    #[test]
    fn claims_are_atomic_and_never_touch_referenced_blocks() {
        let mut p = BlockPool::new(4);
        let held = p.alloc().unwrap(); // referenced by a "running request"
        assert!(p.claim_blocks(4).is_none(), "claim must not steal held blocks");
        assert_eq!(p.num_free(), 3, "failed claim leaves the pool untouched");
        assert_eq!(p.budget().adapter_blocks(), 0);
        let claimed = p.claim_blocks(3).unwrap();
        assert!(!claimed.contains(&held));
        assert_eq!(p.ref_count(held), 1);
        p.release_claimed(&claimed);
        p.free(held);
        p.check_invariants().unwrap();
    }

    #[test]
    fn commit_dedups_to_first() {
        let mut p = BlockPool::new(2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        p.commit_hash(a, BlockHash(5));
        p.commit_hash(b, BlockHash(5));
        let hit = p.lookup(BlockHash(5)).unwrap();
        assert_eq!(hit, a);
    }

    #[test]
    fn property_random_ops_keep_invariants() {
        use crate::util::prop;
        prop::check("pool-random-ops", 50, |rng, _| {
            let n = rng.range(1, 16) as u32;
            let mut p = BlockPool::new(n);
            let mut held: Vec<BlockId> = vec![];
            for step in 0..200 {
                match rng.next_below(4) {
                    0 => {
                        if let Some(b) = p.alloc() {
                            if rng.next_below(2) == 0 {
                                p.commit_hash(b, BlockHash(rng.next_below(8)));
                            }
                            held.push(b);
                        }
                    }
                    1 => {
                        if !held.is_empty() {
                            let i = rng.next_below(held.len() as u64) as usize;
                            let b = held.swap_remove(i);
                            p.free(b);
                        }
                    }
                    2 => {
                        if let Some(b) = p.lookup(BlockHash(rng.next_below(8))) {
                            held.push(b);
                        }
                    }
                    _ => {
                        if let Err(e) = p.check_invariants() {
                            return Err(format!("step {step}: {e}"));
                        }
                    }
                }
            }
            p.check_invariants()
        });
    }
}
