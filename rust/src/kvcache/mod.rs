//! PagedAttention-style KV-cache with base-aligned cross-model prefix reuse.
//!
//! - [`block`]: physical block pool, refcounts, LRU free-list reuse.
//! - [`hash`]: chained block hashing primitive with adapter/cache salts.
//! - [`prefix`]: per-request salting policy — where the paper's
//!   base-aligned hashing lives (Figure 3).
//! - [`chain`]: interned, refcounted, prefix-sharing chain arena — cheap
//!   [`ChainRef`] handles replace `Vec<BlockHash>` clones at the
//!   session/submit/lease boundaries.
//! - [`manager`]: per-request block tables, admission, commit, preemption.
//! - [`summary`]: routable sketch of the committed hashes — what a cluster
//!   router reads to score replica affinity without touching the pool.

pub mod block;
pub mod chain;
pub mod hash;
pub mod manager;
pub mod prefix;
pub mod summary;

pub use block::{BlockHash, BlockId, BlockPool, PoolStats};
pub use chain::ChainRef;
pub use manager::{CacheStats, CachedPrefix, KvCacheManager, ReqKey};
pub use prefix::{block_hashes, HashContext};
pub use summary::HashSummary;
