//! Routable summary of a cache's committed block hashes.
//!
//! The cluster router needs to ask "how much of this request's hash chain
//! does replica R already hold?" without walking R's whole block pool (in a
//! real deployment the router is a separate process and replicas publish
//! summaries, not pools). [`HashSummary`] is a counting sketch over the
//! committed hashes: one u32 counter per slot, indexed by `hash % slots`.
//! [`super::block::BlockPool`] feeds it incrementally — +1 when a block's
//! hash is committed, -1 when an eviction drops it — so the summary tracks
//! exactly the set of resurrectable blocks, at O(1) per event.
//!
//! Like any sketch it can report false positives (two hashes sharing a
//! slot), never false negatives; for routing that only means an occasional
//! overestimated affinity score, which the least-loaded tie-break absorbs.

use std::cell::Cell;

use crate::util::fxmap::FxHashMap;

use super::block::BlockHash;
use super::chain::ChainRef;

/// Default slot count: 4096 × 4 bytes = 16 KiB per replica, collision
/// probability ~n/4096 for n committed blocks — plenty for routing.
pub const DEFAULT_SLOTS: usize = 4096;

thread_local! {
    /// Sketch slot reads on this thread since the last [`take_probe_ops`]
    /// — the other half of the placement-cost probe (see
    /// `kvcache::prefix::take_hash_ops`).
    static PROBE_OPS: Cell<u64> = const { Cell::new(0) };
}

/// Drain this thread's sketch-probe op counter (reads and resets).
pub fn take_probe_ops() -> u64 {
    PROBE_OPS.with(|c| c.replace(0))
}

/// One lease's chain registered for incremental affinity: `matched` is
/// kept equal — at all times — to what `matching_prefix(&hashes)` would
/// recompute, by advancing on 0→1 slot transitions at the chain's parked
/// frontier and shrinking on 1→0 transitions inside the matched run.
#[derive(Debug, Clone)]
struct TrackedChain {
    /// Interned handle to the tracked chain — the O(delta) identity check
    /// for extensions, and what the router's lease hint validates against.
    chain: ChainRef,
    hashes: Vec<BlockHash>,
    slots: Vec<usize>,
    matched: usize,
    /// The slot this chain waits on (`slots[matched]`) when not fully
    /// matched; the frontier index's validity check.
    parked: Option<usize>,
    /// Incarnation tag: stale frontier/member entries from an earlier
    /// `track` of the same key are dropped lazily on touch.
    gen: u64,
}

#[derive(Debug, Clone)]
pub struct HashSummary {
    counts: Vec<u32>,
    /// Live committed hashes (inserts minus removes).
    committed: u64,
    /// Leased/sticky chains maintained incrementally (key = lease key).
    tracked: FxHashMap<u64, TrackedChain>,
    /// slot → chains parked at that slot (their first missing position).
    frontier: FxHashMap<usize, Vec<(u64, u64)>>,
    /// slot → chains whose matched run crossed that slot. May hold stale
    /// or duplicate entries; validated lazily when the slot hits zero.
    members: FxHashMap<usize, Vec<(u64, u64)>>,
    next_gen: u64,
}

impl Default for HashSummary {
    fn default() -> Self {
        Self::new()
    }
}

impl HashSummary {
    pub fn new() -> Self {
        Self::with_slots(DEFAULT_SLOTS)
    }

    pub fn with_slots(slots: usize) -> Self {
        assert!(slots > 0, "empty summary");
        HashSummary {
            counts: vec![0; slots],
            committed: 0,
            tracked: FxHashMap::default(),
            frontier: FxHashMap::default(),
            members: FxHashMap::default(),
            next_gen: 0,
        }
    }

    #[inline]
    fn slot(&self, h: BlockHash) -> usize {
        // Block hashes are already well-mixed (kvcache::hash), so plain
        // modulo distributes evenly.
        (h.0 % self.counts.len() as u64) as usize
    }

    /// One counted sketch read.
    #[inline]
    fn probe(&self, slot: usize) -> bool {
        PROBE_OPS.with(|c| c.set(c.get() + 1));
        self.counts[slot] > 0
    }

    /// A block with this hash was committed (shareable from now on).
    #[inline]
    pub fn insert(&mut self, h: BlockHash) {
        let s = self.slot(h);
        self.counts[s] += 1;
        self.committed += 1;
        if self.counts[s] == 1 {
            self.advance_frontier(s);
        }
    }

    /// A block with this hash was evicted.
    #[inline]
    pub fn remove(&mut self, h: BlockHash) {
        let s = self.slot(h);
        debug_assert!(self.counts[s] > 0, "summary remove without insert");
        self.counts[s] = self.counts[s].saturating_sub(1);
        self.committed = self.committed.saturating_sub(1);
        if self.counts[s] == 0 {
            self.shrink_members(s);
        }
    }

    /// May the cache hold a committed block with this hash? (No false
    /// negatives.)
    #[inline]
    pub fn maybe_contains(&self, h: BlockHash) -> bool {
        self.probe(self.slot(h))
    }

    /// Live committed-hash count (exact, not sketched).
    pub fn committed_blocks(&self) -> u64 {
        self.committed
    }

    /// Length of the leading run of `chain` this summary may contain — the
    /// affinity score a router assigns this cache for a request whose full
    /// block-hash chain is `chain`. Prefix semantics mirror admission
    /// (`KvCacheManager::start_request` stops at the first miss).
    pub fn matching_prefix(&self, chain: &[BlockHash]) -> usize {
        let mut n = 0;
        for &h in chain {
            if self.maybe_contains(h) {
                n += 1;
            } else {
                break;
            }
        }
        n
    }

    // -- tracked chains (incremental affinity) ------------------------------

    /// Register (or extend) a lease's chain for incremental affinity.
    /// When the new chain extends the previously tracked one (the common
    /// delta-turn case) the matched state carries over and only the tail
    /// is scanned — O(delta). Anything else rebuilds from scratch.
    pub fn track(&mut self, key: u64, chain: &ChainRef) {
        let extend = self
            .tracked
            .get(&key)
            .is_some_and(|tc| chain.is_extension_of(&tc.chain));
        if extend {
            let tc = self.tracked.get_mut(&key).expect("checked");
            let old_len = tc.hashes.len();
            // O(delta): read only the tail past the already-tracked run.
            let delta = chain.range(old_len, chain.len());
            tc.hashes.extend_from_slice(&delta);
            tc.chain = chain.clone();
            let new_slots: Vec<usize> = delta
                .iter()
                .map(|h| (h.0 % self.counts.len() as u64) as usize)
                .collect();
            let tc = self.tracked.get_mut(&key).expect("checked");
            tc.slots.extend(new_slots);
            // If the old chain was fully matched the frontier moves into
            // the new tail; a parked chain stays parked where it was.
            if tc.parked.is_none() && tc.matched < tc.slots.len() {
                self.advance_chain(key);
            }
        } else {
            // New or diverged chain: the one place a tracked chain is
            // materialized in full (counted by the chain-op probes).
            self.next_gen += 1;
            let gen = self.next_gen;
            let hashes = chain.hashes();
            let slots: Vec<usize> =
                hashes.iter().map(|h| (h.0 % self.counts.len() as u64) as usize).collect();
            self.tracked.insert(
                key,
                TrackedChain {
                    chain: chain.clone(),
                    hashes,
                    slots,
                    matched: 0,
                    parked: None,
                    gen,
                },
            );
            self.advance_chain(key);
        }
    }

    /// Forget a lease's chain (lease released/broken). Stale index
    /// entries are dropped lazily.
    pub fn untrack(&mut self, key: u64) {
        self.tracked.remove(&key);
    }

    /// Incrementally maintained `(matched, chain_len)` for a tracked
    /// lease — `matched` equals what `matching_prefix` would recompute
    /// over the tracked chain, at O(1).
    pub fn tracked_prefix(&self, key: u64) -> Option<(usize, usize)> {
        self.tracked.get(&key).map(|tc| (tc.matched, tc.hashes.len()))
    }

    /// The hashes registered under a tracked lease (equivalence checks).
    pub fn tracked_chain(&self, key: u64) -> Option<&[BlockHash]> {
        self.tracked.get(&key).map(|tc| tc.hashes.as_slice())
    }

    /// The interned handle registered under a tracked lease — lets the
    /// router validate a lease hint by node identity instead of hash
    /// comparison.
    pub fn tracked_chain_ref(&self, key: u64) -> Option<&ChainRef> {
        self.tracked.get(&key).map(|tc| &tc.chain)
    }

    /// Advance `key`'s matched run over present slots, then park at the
    /// first missing one (if any).
    fn advance_chain(&mut self, key: u64) {
        let Some(tc) = self.tracked.get(&key) else { return };
        let (mut matched, len, gen) = (tc.matched, tc.slots.len(), tc.gen);
        let mut parked = None;
        while matched < len {
            let slot = self.tracked[&key].slots[matched];
            if self.probe(slot) {
                self.members.entry(slot).or_default().push((key, gen));
                matched += 1;
            } else {
                self.frontier.entry(slot).or_default().push((key, gen));
                parked = Some(slot);
                break;
            }
        }
        let tc = self.tracked.get_mut(&key).expect("checked");
        tc.matched = matched;
        tc.parked = parked;
    }

    /// A slot went 0→1: resume every chain validly parked on it.
    fn advance_frontier(&mut self, s: usize) {
        let Some(waiters) = self.frontier.remove(&s) else { return };
        for (key, gen) in waiters {
            let valid = self
                .tracked
                .get(&key)
                .is_some_and(|tc| tc.gen == gen && tc.parked == Some(s));
            if valid {
                self.advance_chain(key);
            }
        }
    }

    /// A slot went 1→0: shrink every chain whose matched run crosses it
    /// back to the slot's first occurrence (exactly where
    /// `matching_prefix` would now stop) and re-park there.
    fn shrink_members(&mut self, s: usize) {
        let Some(entries) = self.members.remove(&s) else { return };
        for (key, gen) in entries {
            let Some(tc) = self.tracked.get_mut(&key) else { continue };
            if tc.gen != gen {
                continue;
            }
            if let Some(pos) = tc.slots[..tc.matched].iter().position(|&x| x == s) {
                tc.matched = pos;
                tc.parked = Some(s);
                let gen = tc.gen;
                self.frontier.entry(s).or_default().push((key, gen));
            }
        }
    }

    /// Test hook: every tracked chain's `matched` must equal a fresh
    /// recompute from the sketch, and parked chains must hold a valid
    /// frontier entry.
    #[doc(hidden)]
    pub fn check_tracked(&self) -> Result<(), String> {
        for (key, tc) in &self.tracked {
            let expect =
                tc.slots.iter().take_while(|&&s| self.counts[s] > 0).count();
            if tc.matched != expect {
                return Err(format!(
                    "tracked chain {key}: matched {} but sketch recompute says {expect}",
                    tc.matched
                ));
            }
            if tc.matched < tc.slots.len() {
                let s = tc.slots[tc.matched];
                if tc.parked != Some(s) {
                    return Err(format!("tracked chain {key}: not parked at its frontier"));
                }
                let has_entry = self
                    .frontier
                    .get(&s)
                    .is_some_and(|v| v.iter().any(|&(k, g)| k == *key && g == tc.gen));
                if !has_entry {
                    return Err(format!("tracked chain {key}: missing frontier entry"));
                }
            } else if tc.parked.is_some() {
                return Err(format!("tracked chain {key}: fully matched but parked"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h(x: u64) -> BlockHash {
        // Spread values so tests don't collide in the default sketch.
        BlockHash(x.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[test]
    fn insert_remove_roundtrip() {
        let mut s = HashSummary::new();
        assert!(!s.maybe_contains(h(1)));
        s.insert(h(1));
        assert!(s.maybe_contains(h(1)));
        assert_eq!(s.committed_blocks(), 1);
        s.remove(h(1));
        assert!(!s.maybe_contains(h(1)));
        assert_eq!(s.committed_blocks(), 0);
    }

    #[test]
    fn duplicate_hashes_counted() {
        // Two physical blocks may commit the same content hash; the slot
        // must survive one of them being evicted.
        let mut s = HashSummary::new();
        s.insert(h(7));
        s.insert(h(7));
        s.remove(h(7));
        assert!(s.maybe_contains(h(7)));
        s.remove(h(7));
        assert!(!s.maybe_contains(h(7)));
    }

    #[test]
    fn matching_prefix_stops_at_first_miss() {
        let mut s = HashSummary::new();
        let chain: Vec<BlockHash> = (0..6).map(h).collect();
        for &x in &chain[..3] {
            s.insert(x);
        }
        s.insert(chain[4]); // present but unreachable past the gap at [3]
        assert_eq!(s.matching_prefix(&chain), 3);
        assert_eq!(s.matching_prefix(&chain[..2]), 2);
        assert_eq!(s.matching_prefix(&[]), 0);
    }

    #[test]
    fn pool_commit_evict_symmetry_returns_to_zero() {
        // Driven through the real BlockPool feed (+1 on commit, −1 on
        // eviction): after every cached block is evicted, the summary is
        // exactly empty again — counts AND the committed total.
        use super::super::block::BlockPool;
        let mut p = BlockPool::new(8);
        let hashes: Vec<BlockHash> = (1..=8).map(h).collect();
        let mut held = Vec::new();
        for &hash in &hashes {
            let b = p.alloc().unwrap();
            p.commit_hash(b, hash);
            held.push(b);
        }
        assert_eq!(p.routing_summary().committed_blocks(), 8);
        for b in held {
            p.free(b);
        }
        // Full eviction: 8 fresh allocations overwrite every cached block.
        for _ in 0..8 {
            p.alloc().unwrap();
        }
        assert_eq!(p.routing_summary().committed_blocks(), 0);
        for &hash in &hashes {
            assert!(!p.routing_summary().maybe_contains(hash), "{hash:?} lingers");
        }
        assert_eq!(p.routing_summary().matching_prefix(&hashes), 0);
    }

    #[test]
    fn single_slot_saturation_counts_exactly() {
        // Every hash lands in the one slot: the counter must track the
        // multiset size exactly — present until the LAST remove, absent
        // after — rather than flipping on the first.
        let mut s = HashSummary::with_slots(1);
        let k = 100;
        for i in 0..k {
            s.insert(h(i));
        }
        assert_eq!(s.committed_blocks(), k);
        assert!(s.maybe_contains(h(7777)), "one slot: everything aliases");
        for i in 0..k - 1 {
            s.remove(h(i));
            assert!(s.maybe_contains(h(k - 1)), "removed {i}, slot must survive");
        }
        s.remove(h(k - 1));
        assert!(!s.maybe_contains(h(0)));
        assert_eq!(s.committed_blocks(), 0);
    }

    #[test]
    fn routing_scores_deterministic_across_replicas() {
        // Two replicas fed the identical commit/evict sequence must score
        // any probe chain identically — PrefixAffinity depends on it (a
        // divergent sketch would route the same request differently on
        // re-runs). Exercised through two independent pools.
        use super::super::block::BlockPool;
        let drive = || {
            let mut p = BlockPool::new(16);
            let mut held = Vec::new();
            for x in 0..12u64 {
                let b = p.alloc().unwrap();
                p.commit_hash(b, h(x));
                held.push(b);
            }
            for b in held.drain(..6) {
                p.free(b);
            }
            // 8 fresh allocations: the 4 never-hashed spares first, then
            // 4 of the 6 freed blocks — evicting h(0)..h(3).
            for _ in 0..8 {
                p.alloc().unwrap();
            }
            p
        };
        let (a, b) = (drive(), drive());
        let chain: Vec<BlockHash> = (0..12).map(h).collect();
        for len in 0..=chain.len() {
            assert_eq!(
                a.routing_summary().matching_prefix(&chain[..len]),
                b.routing_summary().matching_prefix(&chain[..len]),
                "replicas disagree at chain length {len}"
            );
        }
        assert_eq!(
            a.routing_summary().committed_blocks(),
            b.routing_summary().committed_blocks()
        );
    }

    #[test]
    fn no_false_negatives_under_churn() {
        use crate::util::prop;
        prop::check("summary-churn", 20, |rng, _| {
            let mut s = HashSummary::with_slots(64); // force collisions
            let mut live: Vec<BlockHash> = Vec::new();
            for _ in 0..300 {
                if rng.next_below(2) == 0 {
                    let x = h(rng.next_below(1 << 20));
                    s.insert(x);
                    live.push(x);
                } else if let Some(x) = live.pop() {
                    s.remove(x);
                }
                for x in &live {
                    if !s.maybe_contains(*x) {
                        return Err(format!("false negative for {x:?}"));
                    }
                }
            }
            if s.committed_blocks() != live.len() as u64 {
                return Err("committed count drifted".into());
            }
            Ok(())
        });
    }
}
