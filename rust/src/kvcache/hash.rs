//! Chained block hashing with base-aligned adapter semantics — the paper's
//! core mechanism (Figure 3).
//!
//! vLLM hashes each full KV block over (parent hash, tokens in block, extra
//! keys). The extra keys normally include the adapter ID, isolating every
//! adapter's cache. Our modification: for aLoRA requests, blocks consisting
//! entirely of *pre-activation* tokens omit the adapter ID — because their
//! K/V are bit-identical to the base model's, base and aLoRA blocks become
//! interchangeable in both directions. Blocks containing any post-activation
//! token, and all blocks of standard-LoRA requests, keep the salt.

use super::block::BlockHash;

/// FxHash-style multiply-xor mix: fast, deterministic, good avalanche for
/// token streams. Not cryptographic — same trust model as vLLM's default
/// builtin-hash mode (cache keys, not signatures).
const K: u64 = 0x517c_c1b7_2722_0a95;

#[inline]
fn mix(h: u64, x: u64) -> u64 {
    (h.rotate_left(5) ^ x).wrapping_mul(K)
}

/// Seed distinguishing the hash chain root so that block hashes can never
/// collide with raw token values.
const ROOT: u64 = 0x9E37_79B9_7F4A_7C15;

/// Extra keys folded into a block's hash (vLLM: lora id + cache salt).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ExtraKeys {
    /// Internal adapter ID; None = hash as the base model. The base-aligned
    /// policy (prefix::HashContext) decides when this is None for aLoRA.
    pub adapter_salt: Option<u32>,
    /// vLLM-style cache salt for multi-tenant isolation (0 = none).
    pub cache_salt: u64,
}

/// Derive a multi-tenant cache salt from a tenant identifier string.
/// Guaranteed nonzero (0 means "no salt" throughout the cache layer), and
/// stable across runs so tenants keep hitting their own cached prefixes.
pub fn tenant_salt(tenant: &str) -> u64 {
    let mut h = ROOT;
    for b in tenant.bytes() {
        h = mix(h, b as u64 + 1);
    }
    h = mix(h, 0x7E4A);
    if h == 0 {
        1
    } else {
        h
    }
}

/// Hash one full block given its parent's hash (None for the first block),
/// the tokens inside the block, and the extra keys.
pub fn block_hash(parent: Option<BlockHash>, tokens: &[u32], extra: ExtraKeys) -> BlockHash {
    let mut h = match parent {
        Some(BlockHash(p)) => mix(ROOT, p),
        None => ROOT,
    };
    for &t in tokens {
        h = mix(h, t as u64 + 1); // +1 so token 0 != "no token"
    }
    match extra.adapter_salt {
        // Distinct tags keep (no adapter) and (adapter 0) apart.
        Some(id) => {
            h = mix(h, 0xAD11);
            h = mix(h, id as u64 + 1);
        }
        None => h = mix(h, 0xBA5E),
    }
    if extra.cache_salt != 0 {
        h = mix(h, extra.cache_salt);
    }
    BlockHash(h)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bh(parent: Option<BlockHash>, toks: &[u32], salt: Option<u32>) -> BlockHash {
        block_hash(parent, toks, ExtraKeys { adapter_salt: salt, cache_salt: 0 })
    }

    #[test]
    fn deterministic() {
        assert_eq!(bh(None, &[1, 2, 3], None), bh(None, &[1, 2, 3], None));
    }

    #[test]
    fn tokens_change_hash() {
        assert_ne!(bh(None, &[1, 2, 3], None), bh(None, &[1, 2, 4], None));
        assert_ne!(bh(None, &[1, 2], None), bh(None, &[1, 2, 0], None));
    }

    #[test]
    fn chaining_captures_history() {
        let p1 = bh(None, &[1, 2], None);
        let p2 = bh(None, &[9, 9], None);
        assert_ne!(bh(Some(p1), &[5, 6], None), bh(Some(p2), &[5, 6], None));
    }

    #[test]
    fn adapter_salt_isolates() {
        let base = bh(None, &[1, 2, 3], None);
        let a0 = bh(None, &[1, 2, 3], Some(0));
        let a1 = bh(None, &[1, 2, 3], Some(1));
        assert_ne!(base, a0);
        assert_ne!(base, a1);
        assert_ne!(a0, a1);
    }

    #[test]
    fn cache_salt_isolates() {
        let a = block_hash(None, &[1], ExtraKeys { adapter_salt: None, cache_salt: 0 });
        let b = block_hash(None, &[1], ExtraKeys { adapter_salt: None, cache_salt: 7 });
        assert_ne!(a, b);
    }

    #[test]
    fn tenant_salt_stable_nonzero_distinct() {
        assert_eq!(tenant_salt("acme"), tenant_salt("acme"));
        assert_ne!(tenant_salt("acme"), tenant_salt("acme2"));
        assert_ne!(tenant_salt(""), 0);
        assert_ne!(tenant_salt("acme"), 0);
    }

    #[test]
    fn base_aligned_blocks_collide_on_purpose() {
        // The whole point: an aLoRA pre-activation block hashed with salt
        // None equals the base model's block hash for the same tokens.
        let base = bh(None, &[10, 11, 12], None);
        let alora_pre = bh(None, &[10, 11, 12], None);
        assert_eq!(base, alora_pre);
    }

    #[test]
    fn avalanche_smoke() {
        // Flipping one token bit should flip ~half the hash bits on average.
        let h1 = bh(None, &[100, 200, 300, 400], None).0;
        let h2 = bh(None, &[100, 200, 301, 400], None).0;
        let flipped = (h1 ^ h2).count_ones();
        assert!((16..=48).contains(&flipped), "weak avalanche: {flipped} bits");
    }

    #[test]
    fn property_no_collisions_across_random_chains() {
        use crate::util::prop;
        use std::collections::HashSet;
        prop::check("hash-collisions", 20, |rng, _| {
            let mut seen = HashSet::new();
            let mut parent = None;
            for _ in 0..500 {
                let n = rng.range(1, 17) as usize;
                let toks: Vec<u32> = (0..n).map(|_| rng.next_below(50_000) as u32).collect();
                let salt = if rng.next_below(3) == 0 {
                    Some(rng.next_below(8) as u32)
                } else {
                    None
                };
                let h = bh(parent, &toks, salt);
                if !seen.insert(h.0) {
                    return Err(format!("collision at {h:?}"));
                }
                parent = Some(h);
            }
            Ok(())
        });
    }
}
