//! Adapter-weight residency: ref-counted loads with LRU eviction, paged
//! against the unified KV memory budget — now with a time-costed
//! two-tier model (DESIGN.md §20).
//!
//! Before this module the engine pretended every registered adapter's
//! weights were permanently GPU-resident — free capacity the KV cache
//! never saw. S-LoRA (arXiv 2311.03285) serves thousands of adapters by
//! paging weights in the same unified pool as KV cache; this manager is
//! that policy layer over [`crate::memory::MemoryBudget`]:
//!
//! - **Load** claims `weight_blocks` pages from the shared
//!   [`crate::kvcache::KvCacheManager`] pool (evicting cold cached KV
//!   content if needed, never referenced blocks). With a configured
//!   transfer cost a load is a STATE MACHINE, not an event: the entry
//!   sits in `Loading` until its modeled host→device transfer completes
//!   at `ready_at` on the sim clock, and admission stalls (counted in
//!   [`ResidencyStats::load_stall_steps`]) until it matures. With the
//!   default zero cost, loads complete inline — bit-identical to the
//!   instantaneous accounting this module started as.
//! - **Refs** count running requests using the adapter. Admission acquires,
//!   preemption and completion release; at zero refs the adapter stays
//!   resident (warm) but becomes evictable.
//! - **Eviction** is LRU over idle (ref == 0, fully loaded) residents,
//!   triggered when a load or a KV allocation needs room — the two sides
//!   reclaim from each other under one policy (FASTLIBRA-style
//!   co-management). With a host tier configured, device eviction
//!   *demotes* the weights to pinned host memory (a later reload skips
//!   the setup cost — strictly cheaper); only host-tier pressure *drops*
//!   them outright (full-cost reload).
//! - **Prefetch** (scheduler-driven): a queued request's cold adapter can
//!   start its transfer while the request waits for admission,
//!   overlapping load with queue time.

use crate::config::ModelConfig;
use crate::kvcache::block::BlockId;
use crate::kvcache::manager::KvCacheManager;
use crate::util::fxmap::FxHashMap;

use super::{AdapterId, AdapterRegistry};

/// Counters exported through the metrics registry
/// (`alora_serve_adapter_*`) and `GET /cluster`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Full-cost weight loads performed (adapter became device-resident
    /// from cold — host-tier promotions are counted separately).
    pub loads: u64,
    /// Idle adapters evicted from the device to reclaim memory
    /// (demotions included — an eviction that found host room is still
    /// an eviction).
    pub evictions: u64,
    /// Scheduler steps where admission stalled on adapter weights —
    /// either the load could not claim memory or its transfer was still
    /// in flight.
    pub load_stall_steps: u64,
    /// Adapter-targeted admissions.
    pub adapter_admissions: u64,
    /// ...whose adapter was already resident (no load on the critical path).
    pub adapter_admission_hits: u64,
    /// Device evictions that parked the weights in the host tier.
    pub demotions: u64,
    /// Loads served from the host tier (setup cost skipped).
    pub promotions: u64,
    /// Host-tier entries dropped under host pressure (next use pays a
    /// full-cost reload).
    pub host_drops: u64,
    /// Loads started by the scheduler's prefetch pass (overlapping
    /// transfer with queue wait) rather than at admission.
    pub prefetches: u64,
}

impl ResidencyStats {
    /// Fraction of adapter admissions that found their weights resident —
    /// the residency analogue of the prefix-cache hit rate.
    pub fn hit_rate(&self) -> f64 {
        if self.adapter_admissions == 0 {
            0.0
        } else {
            self.adapter_admission_hits as f64 / self.adapter_admissions as f64
        }
    }
}

/// Device-entry transfer state (DESIGN.md §20). `Loading` entries hold
/// their claimed pages (the budget is charged for the whole transfer)
/// but cannot serve admissions or be evicted until they mature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DeviceState {
    Loading,
    Ready,
}

#[derive(Debug)]
struct Resident {
    /// Pages claimed from the shared pool (hashless, budget-charged).
    blocks: Vec<BlockId>,
    /// Running requests currently using this adapter.
    refs: u32,
    /// Monotonic LRU stamp (load / acquire / release all touch it).
    last_used: u64,
    /// Transfer state; `Ready` immediately under zero-cost config.
    state: DeviceState,
    /// Sim time at which an in-flight transfer completes (== the load's
    /// start time under zero-cost config).
    ready_at: f64,
}

/// A demoted adapter parked in pinned host memory: no physical
/// `BlockId`s (the device pool never sees the host tier), just a block
/// count charged against the host ledger and an LRU stamp.
#[derive(Debug)]
struct HostEntry {
    blocks: usize,
    last_used: u64,
}

/// What an admission attempt learned about its adapter's weights.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmitGate {
    /// Already resident and ready — a warm hit.
    Hit,
    /// A load (or promotion) completed inline: zero-cost config, or a
    /// transfer that matured exactly now. The admission is cold.
    LoadedNow,
    /// Transfer in flight; ready at the contained sim time. The caller
    /// defers admission and counts a stall.
    Loading(f64),
    /// Memory not reclaimable right now — the caller defers admission
    /// and counts a stall.
    NoMemory,
}

/// Ref-counted adapter-weight residency with LRU eviction of idle
/// adapters, charging against the same block budget as KV allocation.
#[derive(Debug)]
pub struct AdapterResidency {
    enabled: bool,
    /// Per-adapter weight cost in KV-block-equivalents (registry order).
    weight_blocks: Vec<usize>,
    resident: FxHashMap<u32, Resident>,
    /// Demoted adapters parked in the host tier (DESIGN.md §20).
    host: FxHashMap<u32, HostEntry>,
    tick: u64,
    stats: ResidencyStats,
    /// Fixed setup cost of a cold host→device load, seconds (0 = the
    /// instantaneous-accounting default).
    load_setup_s: f64,
    /// Per-block transfer cost, seconds; promotions pay only this slope.
    load_per_block_s: f64,
    /// Scheduler prefetch opt-in (`cache.adapter_prefetch`).
    prefetch: bool,
}

impl AdapterResidency {
    /// Derive per-adapter weight costs from the registry and model dims.
    /// With `enabled = false` this is the pre-paging always-resident model:
    /// every query reports resident, nothing is charged, no stats move.
    pub fn new(
        registry: &AdapterRegistry,
        model: &ModelConfig,
        block_size: u32,
        enabled: bool,
    ) -> Self {
        AdapterResidency {
            enabled,
            weight_blocks: registry
                .iter()
                .map(|a| a.weight_blocks(model, block_size))
                .collect(),
            resident: FxHashMap::default(),
            host: FxHashMap::default(),
            tick: 0,
            stats: ResidencyStats::default(),
            load_setup_s: 0.0,
            load_per_block_s: 0.0,
            prefetch: false,
        }
    }

    /// Always-resident stub for tests and adapter-free fixtures.
    pub fn disabled() -> Self {
        AdapterResidency {
            enabled: false,
            weight_blocks: Vec::new(),
            resident: FxHashMap::default(),
            host: FxHashMap::default(),
            tick: 0,
            stats: ResidencyStats::default(),
            load_setup_s: 0.0,
            load_per_block_s: 0.0,
            prefetch: false,
        }
    }

    /// Configure the transfer-cost model and the prefetch opt-in
    /// (construction-time; mirrors `CostModel::adapter_load_time`). The
    /// defaults — all zero, prefetch off — keep every load inline and
    /// instantaneous, bit-identical to the pre-tiering engine.
    pub fn configure_tiering(&mut self, setup_s: f64, per_block_s: f64, prefetch: bool) {
        self.load_setup_s = setup_s;
        self.load_per_block_s = per_block_s;
        self.prefetch = prefetch;
    }

    /// Is the scheduler's prefetch pass enabled?
    pub fn prefetch_enabled(&self) -> bool {
        self.enabled && self.prefetch
    }

    /// Modeled cold-load transfer time for `blocks` weight pages.
    fn cold_load_time(&self, blocks: usize) -> f64 {
        if self.load_per_block_s == 0.0 && self.load_setup_s == 0.0 {
            return 0.0;
        }
        self.load_setup_s + blocks as f64 * self.load_per_block_s
    }

    /// Modeled promotion time: pure bandwidth, no setup — the demoted
    /// weights stay staged and pinned on the host (DESIGN.md §20).
    fn promote_time(&self, blocks: usize) -> f64 {
        blocks as f64 * self.load_per_block_s
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn stats(&self) -> ResidencyStats {
        self.stats
    }

    /// Weight cost of one adapter in blocks; 0 when paging is disabled
    /// (weights are free under always-resident semantics). An id outside
    /// the registry is a caller bug: it trips a debug assertion, and in
    /// release builds conservatively costs 1 block rather than silently
    /// under-charging as 0 would.
    pub fn weight_blocks_of(&self, aid: AdapterId) -> usize {
        if !self.enabled {
            return 0;
        }
        match self.weight_blocks.get(aid.0 as usize) {
            Some(&n) => n,
            None => {
                debug_assert!(
                    false,
                    "weight_blocks_of: adapter id {} not in registry (len {})",
                    aid.0,
                    self.weight_blocks.len()
                );
                1
            }
        }
    }

    pub fn is_resident(&self, aid: AdapterId) -> bool {
        !self.enabled || self.resident.contains_key(&aid.0)
    }

    /// Is `aid` parked in the host tier awaiting promotion?
    pub fn is_host_resident(&self, aid: AdapterId) -> bool {
        self.enabled && self.host.contains_key(&aid.0)
    }

    /// Blocks an admission of `adapter` would add for weights on top of its
    /// KV demand — the admission watermark's adapter-load term. An entry
    /// already `Loading` has claimed its pages, so it reports 0.
    pub fn pending_load_blocks(&self, adapter: Option<AdapterId>) -> usize {
        match adapter {
            Some(aid) if self.enabled && !self.resident.contains_key(&aid.0) => {
                self.weight_blocks_of(aid)
            }
            _ => 0,
        }
    }

    /// Resident adapter ids, ascending (stable for stats/JSON).
    pub fn resident_ids(&self) -> Vec<u32> {
        let mut ids: Vec<u32> = self.resident.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Host-tier adapter ids, ascending (stable for stats/JSON).
    pub fn host_resident_ids(&self) -> Vec<u32> {
        let mut ids: Vec<u32> = self.host.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    pub fn num_resident(&self) -> usize {
        self.resident.len()
    }

    /// Total pages currently charged to adapter weights on the device.
    pub fn resident_blocks(&self) -> usize {
        self.resident.values().map(|e| e.blocks.len()).sum()
    }

    /// Total block-equivalents charged to demoted weights on the host.
    pub fn host_resident_blocks(&self) -> usize {
        self.host.values().map(|e| e.blocks).sum()
    }

    fn touch(&mut self) -> u64 {
        let t = self.tick;
        self.tick += 1;
        t
    }

    /// Mature every in-flight transfer whose `ready_at` has passed. The
    /// engine calls this once per step before scheduling; the admission
    /// gate also settles its own target lazily.
    pub fn settle(&mut self, now: f64) {
        if !self.enabled {
            return;
        }
        for e in self.resident.values_mut() {
            if e.state == DeviceState::Loading && e.ready_at <= now {
                e.state = DeviceState::Ready;
            }
        }
    }

    /// Earliest completion time among in-flight transfers — the engine's
    /// clock-advance target when nothing else is runnable (an admission
    /// stalled on a transfer must see time pass, or the sim would wedge).
    pub fn earliest_pending_ready(&self) -> Option<f64> {
        self.resident
            .values()
            .filter(|e| e.state == DeviceState::Loading)
            .map(|e| e.ready_at)
            .min_by(|a, b| a.partial_cmp(b).expect("NaN ready_at"))
    }

    /// Start (or observe) the residency of `aid` for an admission at sim
    /// time `now` — the tiering state machine's single entry point:
    ///
    /// - already `Ready` → [`AdmitGate::Hit`];
    /// - already `Loading` → [`AdmitGate::Loading`] (matures via
    ///   [`Self::settle`] once `now` passes `ready_at`);
    /// - absent → claim pages (LRU-evicting idle adapters as needed) and
    ///   start the transfer: a host-tier hit promotes (no setup cost), a
    ///   cold load pays setup + bandwidth. Zero modeled cost completes
    ///   inline ([`AdmitGate::LoadedNow`] — the PR-3 instantaneous path);
    /// - pages unclaimable → [`AdmitGate::NoMemory`].
    pub fn admission_gate(
        &mut self,
        aid: AdapterId,
        kv: &mut KvCacheManager,
        now: f64,
    ) -> AdmitGate {
        if !self.enabled {
            return AdmitGate::Hit;
        }
        if let Some(e) = self.resident.get_mut(&aid.0) {
            if e.state == DeviceState::Loading && e.ready_at <= now {
                e.state = DeviceState::Ready;
            }
            return match e.state {
                DeviceState::Ready => AdmitGate::Hit,
                DeviceState::Loading => AdmitGate::Loading(e.ready_at),
            };
        }
        match self.start_load(aid, kv, now) {
            None => AdmitGate::NoMemory,
            Some(ready_at) if ready_at <= now => {
                self.resident.get_mut(&aid.0).expect("just inserted").state =
                    DeviceState::Ready;
                AdmitGate::LoadedNow
            }
            Some(ready_at) => AdmitGate::Loading(ready_at),
        }
    }

    /// Claim pages and start the transfer for an absent adapter. Returns
    /// the transfer's completion time, or None when memory is not
    /// reclaimable. The entry is inserted as `Loading` with its pages
    /// charged; callers settle it against `now`.
    fn start_load(
        &mut self,
        aid: AdapterId,
        kv: &mut KvCacheManager,
        now: f64,
    ) -> Option<f64> {
        let need = self.weight_blocks_of(aid);
        // A host-tier hit is a promotion: the staged host copy converts
        // into the device copy, so its host charge is released UP FRONT —
        // before any demotion this load's evictions trigger competes for
        // host room (otherwise promoting could drop its own staged copy).
        let promoted = if let Some(h) = self.host.remove(&aid.0) {
            kv.release_host_adapter_blocks(h.blocks);
            true
        } else {
            false
        };
        loop {
            if let Some(blocks) = kv.claim_adapter_blocks(need) {
                let cost = if promoted {
                    self.stats.promotions += 1;
                    self.promote_time(need)
                } else {
                    self.stats.loads += 1;
                    self.cold_load_time(need)
                };
                let t = self.touch();
                self.resident.insert(
                    aid.0,
                    Resident {
                        blocks,
                        refs: 0,
                        last_used: t,
                        state: DeviceState::Loading,
                        ready_at: now + cost,
                    },
                );
                return Some(now + cost);
            }
            if !self.evict_one_idle_except(kv, Some(aid)) {
                // Failed promotion: re-park the staged copy if the tier
                // still has room (this load's demotions may have taken
                // it); otherwise the staged weights are lost too.
                if promoted {
                    if kv.charge_host_adapter_blocks(need) {
                        let t = self.touch();
                        self.host.insert(aid.0, HostEntry { blocks: need, last_used: t });
                    } else {
                        self.stats.host_drops += 1;
                    }
                }
                return None;
            }
        }
    }

    /// Make `aid` resident, loading its weights if needed (the legacy
    /// entry point: transfer time is started at sim time 0.0, so under a
    /// costed config the entry may still be `Loading` — use
    /// [`Self::admission_gate`] on the scheduler path). False = memory
    /// not reclaimable right now.
    pub fn ensure_resident(&mut self, aid: AdapterId, kv: &mut KvCacheManager) -> bool {
        !matches!(self.admission_gate(aid, kv, 0.0), AdmitGate::NoMemory)
    }

    /// Scheduler prefetch (DESIGN.md §20): start a queued request's cold
    /// adapter transfer so it overlaps queue wait. Quiet best-effort — a
    /// failed claim is NOT a stall (the request wasn't admissible anyway)
    /// and a zero-cost config makes this a no-op (nothing to overlap).
    /// True iff a transfer was started.
    pub fn try_prefetch(&mut self, aid: AdapterId, kv: &mut KvCacheManager, now: f64) -> bool {
        if !self.prefetch_enabled() || self.resident.contains_key(&aid.0) {
            return false;
        }
        if self.cold_load_time(self.weight_blocks_of(aid)) == 0.0 {
            return false;
        }
        if self.start_load(aid, kv, now).is_some() {
            self.stats.prefetches += 1;
            true
        } else {
            false
        }
    }

    /// Count an adapter admission: bump the adapter's ref (it must be
    /// resident — the scheduler calls [`Self::admission_gate`] first) and
    /// record whether the weights were already warm when admission began.
    pub fn acquire(&mut self, aid: AdapterId, was_resident: bool) {
        if !self.enabled {
            return;
        }
        self.stats.adapter_admissions += 1;
        if was_resident {
            self.stats.adapter_admission_hits += 1;
        }
        let t = self.touch();
        let e = self
            .resident
            .get_mut(&aid.0)
            .expect("acquire of a non-resident adapter");
        debug_assert_eq!(e.state, DeviceState::Ready, "acquire of an in-flight load");
        e.refs += 1;
        e.last_used = t;
    }

    /// A running request using `aid` left the running set (finished or
    /// preempted). At zero refs the adapter stays warm but evictable.
    pub fn release(&mut self, aid: AdapterId) {
        if !self.enabled {
            return;
        }
        let t = self.touch();
        let e = self
            .resident
            .get_mut(&aid.0)
            .expect("release of a non-resident adapter");
        assert!(e.refs > 0, "release without acquire for adapter {}", aid.0);
        e.refs -= 1;
        e.last_used = t;
    }

    /// Evict the least-recently-used idle adapter (ref == 0, fully
    /// loaded), returning its pages to the shared pool — and, with a host
    /// tier configured, demoting the weights there instead of dropping
    /// them. False when no adapter is evictable.
    pub fn evict_one_idle(&mut self, kv: &mut KvCacheManager) -> bool {
        self.evict_one_idle_except(kv, None)
    }

    /// [`Self::evict_one_idle`] excluding one id — a load in progress must
    /// not evict the adapter it is loading.
    pub fn evict_one_idle_except(
        &mut self,
        kv: &mut KvCacheManager,
        except: Option<AdapterId>,
    ) -> bool {
        self.evict_inner(kv, except, true)
    }

    /// Eviction core. `demote` gates the host tier: the failover path
    /// evicts with `demote = false` because the device's pages are GONE —
    /// there is nothing to stage host-side.
    fn evict_inner(
        &mut self,
        kv: &mut KvCacheManager,
        except: Option<AdapterId>,
        demote: bool,
    ) -> bool {
        if !self.enabled {
            return false;
        }
        // Deterministic LRU: `last_used` stamps are unique (monotonic
        // tick), so the min is unambiguous regardless of map order; the
        // id tie-break is belt-and-suspenders for a future stamp scheme.
        // In-flight loads are skipped — their pages hold a transfer.
        let victim = self
            .resident
            .iter()
            .filter(|(id, e)| {
                e.refs == 0
                    && e.state == DeviceState::Ready
                    && Some(AdapterId(**id)) != except
            })
            .min_by_key(|(id, e)| (e.last_used, **id))
            .map(|(id, _)| *id);
        match victim {
            Some(id) => {
                let e = self.resident.remove(&id).expect("victim vanished");
                let n = e.blocks.len();
                kv.release_adapter_blocks(&e.blocks);
                self.stats.evictions += 1;
                if demote && kv.budget().host_total_blocks() > 0 {
                    self.demote_to_host(id, n, kv);
                }
                true
            }
            None => false,
        }
    }

    /// Park an evicted adapter's weights in the host tier, dropping
    /// host-LRU entries until the charge fits. If the weights exceed the
    /// whole host capacity they are dropped outright (a plain eviction).
    fn demote_to_host(&mut self, id: u32, blocks: usize, kv: &mut KvCacheManager) {
        while !kv.charge_host_adapter_blocks(blocks) {
            let victim = self
                .host
                .iter()
                .min_by_key(|(hid, e)| (e.last_used, **hid))
                .map(|(hid, _)| *hid);
            match victim {
                Some(hid) => {
                    let dropped = self.host.remove(&hid).expect("host victim vanished");
                    kv.release_host_adapter_blocks(dropped.blocks);
                    self.stats.host_drops += 1;
                }
                None => return, // weights larger than the whole tier: drop
            }
        }
        let t = self.touch();
        self.host.insert(id, HostEntry { blocks, last_used: t });
        self.stats.demotions += 1;
    }

    /// Evict every idle resident (replica failover: the device's weight
    /// pages are gone; the caller has already released all refs). Never
    /// demotes — a dead device has nothing to stage host-side. Returns
    /// adapters evicted.
    pub fn evict_all_idle(&mut self, kv: &mut KvCacheManager) -> usize {
        let mut n = 0;
        while self.evict_inner(kv, None, false) {
            n += 1;
        }
        n
    }

    /// Count one scheduler step that stalled admission on adapter weights.
    pub fn note_stall(&mut self) {
        if self.enabled {
            self.stats.load_stall_steps += 1;
        }
    }

    /// Test hook: per-entry consistency (page counts match the cost
    /// model, host charge matches the host map).
    #[doc(hidden)]
    pub fn check_invariants(&self) -> Result<(), String> {
        for (id, e) in &self.resident {
            let want = match self.weight_blocks.get(*id as usize) {
                Some(&n) => n,
                None => return Err(format!("adapter {id} resident but not in registry")),
            };
            if e.blocks.len() != want {
                return Err(format!(
                    "adapter {id}: holds {} pages, cost model says {want}",
                    e.blocks.len()
                ));
            }
            if self.host.contains_key(id) {
                return Err(format!("adapter {id} resident on BOTH tiers"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    /// 3 rank-32 aLoRAs on the tiny model: 8 pages each (see
    /// `adapter::tests::weight_cost_model_scales_with_rank_and_quantizes_up`).
    fn fixture(pool_blocks: u32) -> (AdapterResidency, KvCacheManager) {
        let reg = AdapterRegistry::tiny_default(3, 512, 4);
        let model = presets::tiny().model;
        let res = AdapterResidency::new(&reg, &model, 16, true);
        let kv = KvCacheManager::new(pool_blocks, 16, true);
        (res, kv)
    }

    fn a(i: u32) -> AdapterId {
        AdapterId(i)
    }

    #[test]
    fn load_charges_budget_and_lru_evicts_idle() {
        let (mut res, mut kv) = fixture(20);
        assert!(res.ensure_resident(a(0), &mut kv));
        assert!(res.ensure_resident(a(1), &mut kv));
        assert_eq!(res.resident_blocks(), 16);
        assert_eq!(kv.budget().adapter_blocks(), 16);
        assert_eq!(kv.num_free_blocks(), 4);
        // Third adapter needs 8 pages, only 4 free: the LRU idle adapter
        // (0, loaded first, untouched since) is evicted to make room.
        assert!(res.ensure_resident(a(2), &mut kv));
        assert_eq!(res.resident_ids(), vec![1, 2]);
        assert_eq!(res.stats().loads, 3);
        assert_eq!(res.stats().evictions, 1);
        assert_eq!(kv.budget().adapter_blocks(), 16);
        res.check_invariants().unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn refs_pin_adapters_and_release_unpins() {
        let (mut res, mut kv) = fixture(20);
        assert!(res.ensure_resident(a(0), &mut kv));
        res.acquire(a(0), true);
        assert!(res.ensure_resident(a(1), &mut kv));
        // Adapter 0 is in use: loading 2 must evict 1 (idle), never 0.
        assert!(res.ensure_resident(a(2), &mut kv));
        assert_eq!(res.resident_ids(), vec![0, 2]);
        // Release makes 0 evictable but also touches its LRU stamp, so the
        // next eviction takes 2 (stamped at load, before 0's release).
        res.release(a(0));
        assert!(res.ensure_resident(a(1), &mut kv));
        assert_eq!(res.resident_ids(), vec![0, 1]);
        assert_eq!(res.stats().evictions, 2);
        res.check_invariants().unwrap();
    }

    #[test]
    fn kv_pressure_reclaims_idle_adapters() {
        let (mut res, mut kv) = fixture(16);
        assert!(res.ensure_resident(a(0), &mut kv));
        assert!(res.ensure_resident(a(1), &mut kv));
        assert_eq!(kv.num_free_blocks(), 0);
        // A KV caller under pressure evicts one idle adapter and retries —
        // the other direction of the shared budget.
        assert!(res.evict_one_idle(&mut kv));
        assert_eq!(kv.num_free_blocks(), 8);
        kv.start_request(1, &[], 64);
        assert!(kv.ensure_capacity(1, 64));
        kv.free_request(1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn load_fails_only_when_nothing_is_reclaimable() {
        let (mut res, mut kv) = fixture(16);
        assert!(res.ensure_resident(a(0), &mut kv));
        res.acquire(a(0), true);
        assert!(res.ensure_resident(a(1), &mut kv));
        res.acquire(a(1), false);
        // Both residents pinned, zero free: adapter 2 cannot load.
        assert!(!res.ensure_resident(a(2), &mut kv));
        res.note_stall();
        assert_eq!(res.stats().load_stall_steps, 1);
        // A release unpins 1 → the load now succeeds by evicting it.
        res.release(a(1));
        assert!(res.ensure_resident(a(2), &mut kv));
        assert_eq!(res.resident_ids(), vec![0, 2]);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_hit_accounting() {
        let (mut res, mut kv) = fixture(20);
        let was = res.is_resident(a(0));
        assert!(!was);
        assert!(res.ensure_resident(a(0), &mut kv));
        res.acquire(a(0), was);
        res.release(a(0));
        let was = res.is_resident(a(0));
        assert!(was, "idle resident stays warm");
        assert!(res.ensure_resident(a(0), &mut kv));
        res.acquire(a(0), was);
        let s = res.stats();
        assert_eq!(s.adapter_admissions, 2);
        assert_eq!(s.adapter_admission_hits, 1);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(s.loads, 1, "second admission reused the resident weights");
    }

    #[test]
    fn disabled_is_always_resident_and_free() {
        let mut res = AdapterResidency::disabled();
        let mut kv = KvCacheManager::new(4, 16, true);
        assert!(res.is_resident(a(7)));
        assert_eq!(res.weight_blocks_of(a(7)), 0);
        assert_eq!(res.pending_load_blocks(Some(a(7))), 0);
        assert!(res.ensure_resident(a(7), &mut kv));
        res.acquire(a(7), true);
        res.release(a(7));
        assert!(!res.evict_one_idle(&mut kv));
        res.note_stall();
        assert_eq!(res.stats(), ResidencyStats::default());
        assert_eq!(kv.num_free_blocks(), 4, "nothing charged");
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "not in registry"))]
    fn weight_blocks_of_unknown_id_is_a_bug() {
        // Regression (ISSUE 10 satellite): the old code silently costed
        // unknown ids at 1 block; with paging enabled an out-of-registry
        // id now trips a debug assertion instead of under-charging.
        let (res, _kv) = fixture(20);
        let _ = res.weight_blocks_of(a(99));
    }

    #[test]
    fn costed_load_is_a_state_machine() {
        let (mut res, mut kv) = fixture(20);
        res.configure_tiering(2.0e-3, 1.0e-4, false);
        // Gate at t=1.0: cold load starts, in flight until setup + 8 blocks.
        let g = res.admission_gate(a(0), &mut kv, 1.0);
        let expect_ready = 1.0 + (2.0e-3 + 8.0 * 1.0e-4);
        assert_eq!(g, AdmitGate::Loading(expect_ready));
        assert_eq!(res.stats().loads, 1);
        // Pages are charged for the whole transfer...
        assert_eq!(kv.budget().adapter_blocks(), 8);
        assert_eq!(res.pending_load_blocks(Some(a(0))), 0, "already claimed");
        // ...the entry is resident-but-loading, and cannot be evicted.
        assert!(res.is_resident(a(0)));
        assert!(!res.evict_one_idle(&mut kv), "in-flight load is not evictable");
        // Before ready_at the gate still reports Loading; no second load.
        assert_eq!(res.admission_gate(a(0), &mut kv, 1.001), AdmitGate::Loading(expect_ready));
        assert_eq!(res.stats().loads, 1);
        assert_eq!(res.earliest_pending_ready(), Some(expect_ready));
        // At ready_at it matures into a warm hit.
        assert_eq!(res.admission_gate(a(0), &mut kv, expect_ready), AdmitGate::Hit);
        assert_eq!(res.earliest_pending_ready(), None);
        res.check_invariants().unwrap();
    }

    #[test]
    fn zero_cost_load_completes_inline() {
        let (mut res, mut kv) = fixture(20);
        // Default tiering config: the gate collapses to PR-3 semantics.
        assert_eq!(res.admission_gate(a(0), &mut kv, 5.0), AdmitGate::LoadedNow);
        assert_eq!(res.admission_gate(a(0), &mut kv, 5.0), AdmitGate::Hit);
        assert_eq!(res.earliest_pending_ready(), None);
        assert_eq!(res.stats().loads, 1);
    }

    #[test]
    fn demote_promote_drop_lifecycle() {
        // Pool and host tier each sized for exactly ONE adapter (8 blocks):
        // every load forces an eviction, every eviction a demotion attempt.
        let (mut res, mut kv) = fixture(8);
        kv.set_host_adapter_blocks(8);
        res.configure_tiering(2.0e-3, 1.0e-4, false);
        assert!(matches!(res.admission_gate(a(0), &mut kv, 0.0), AdmitGate::Loading(_)));
        res.settle(1.0);
        assert!(matches!(res.admission_gate(a(1), &mut kv, 1.0), AdmitGate::Loading(_)));
        // Loading 1 at a full pool evicted idle 0 → demoted to host.
        assert_eq!(res.resident_ids(), vec![1]);
        assert_eq!(res.host_resident_ids(), vec![0]);
        assert!(res.is_host_resident(a(0)));
        assert_eq!(res.host_resident_blocks(), 8);
        assert_eq!(kv.budget().host_blocks(), 8);
        let s = res.stats();
        assert_eq!((s.evictions, s.demotions), (1, 1));
        res.settle(2.0);
        // Re-loading 0 is a PROMOTION: no setup cost, host charge released.
        let g = res.admission_gate(a(0), &mut kv, 2.0);
        assert_eq!(g, AdmitGate::Loading(2.0 + 8.0 * 1.0e-4), "promotion skips setup");
        let s = res.stats();
        assert_eq!((s.loads, s.promotions), (2, 1));
        assert!(!res.is_host_resident(a(0)));
        // The promotion released 0's host charge up front, then its
        // eviction of idle 1 demoted 1 into the freed host room.
        assert_eq!(res.host_resident_ids(), vec![1]);
        assert_eq!(kv.budget().host_blocks(), 8, "0 released, 1 charged");
        res.settle(3.0);
        // Host pressure: demoting 0 (via loading 2) drops host-LRU 1.
        assert!(matches!(res.admission_gate(a(2), &mut kv, 3.0), AdmitGate::Loading(_)));
        assert_eq!(res.host_resident_ids(), vec![0]);
        let s = res.stats();
        assert_eq!(s.host_drops, 1, "host-tier pressure drops, never grows");
        assert_eq!(kv.budget().host_blocks(), 8, "exactly one entry charged");
        res.check_invariants().unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn refcounted_adapters_never_demote_mid_use() {
        let (mut res, mut kv) = fixture(16);
        kv.set_host_adapter_blocks(16);
        assert!(res.ensure_resident(a(0), &mut kv));
        res.acquire(a(0), false);
        assert!(res.ensure_resident(a(1), &mut kv));
        res.acquire(a(1), false);
        // Both in use, pool exhausted: nothing evictable, nothing demoted.
        assert!(!res.evict_one_idle(&mut kv));
        assert!(matches!(res.admission_gate(a(2), &mut kv, 0.0), AdmitGate::NoMemory));
        assert_eq!(res.stats().demotions, 0);
        assert_eq!(res.host_resident_blocks(), 0);
        // Released → evictable → demoted.
        res.release(a(0));
        assert!(res.evict_one_idle(&mut kv));
        assert_eq!(res.host_resident_ids(), vec![0]);
        res.check_invariants().unwrap();
    }

    #[test]
    fn host_drop_returns_budget_to_exactly_zero() {
        let (mut res, mut kv) = fixture(20);
        kv.set_host_adapter_blocks(16);
        assert!(res.ensure_resident(a(0), &mut kv));
        assert!(res.ensure_resident(a(1), &mut kv));
        assert!(res.evict_one_idle(&mut kv));
        assert!(res.evict_one_idle(&mut kv));
        assert_eq!(kv.budget().host_blocks(), 16, "both demoted");
        assert_eq!(kv.budget().adapter_blocks(), 0, "device side fully released");
        // A fresh load of a third adapter: both host entries outlive it.
        assert!(res.ensure_resident(a(2), &mut kv));
        assert_eq!(res.host_resident_ids(), vec![0, 1]);
        // Evicting 2 under a FULL host drops host-LRU (0) to make room.
        assert!(res.evict_one_idle(&mut kv));
        assert_eq!(res.host_resident_ids(), vec![1, 2]);
        assert_eq!(res.stats().host_drops, 1);
        assert_eq!(kv.budget().host_blocks(), 16);
        // Failover-style teardown: everything idle drains; host releases
        // land the ledger on exactly zero.
        for id in res.host_resident_ids() {
            let e = res.host.remove(&id).unwrap();
            kv.release_host_adapter_blocks(e.blocks);
        }
        assert_eq!(kv.budget().host_blocks(), 0);
        assert_eq!(kv.budget().host_free_blocks(), 16);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn lru_tie_break_is_deterministic_over_load_order() {
        // Untouched adapters evict in exact load order — the (stamp, id)
        // key is total, so eviction order is reproducible run-to-run.
        let (mut res, mut kv) = fixture(24);
        assert!(res.ensure_resident(a(0), &mut kv));
        assert!(res.ensure_resident(a(1), &mut kv));
        assert!(res.ensure_resident(a(2), &mut kv));
        assert!(res.evict_one_idle(&mut kv));
        assert_eq!(res.resident_ids(), vec![1, 2]);
        assert!(res.evict_one_idle(&mut kv));
        assert_eq!(res.resident_ids(), vec![2]);
        // An acquire/release cycle refreshes the stamp: 2 (just touched)
        // now outlives a reloaded 0.
        assert!(res.ensure_resident(a(0), &mut kv));
        res.acquire(a(2), true);
        res.release(a(2));
        assert!(res.evict_one_idle(&mut kv));
        assert_eq!(res.resident_ids(), vec![2], "refreshed stamp survives");
    }

    #[test]
    fn prefetch_starts_transfer_without_stall_and_counts() {
        let (mut res, mut kv) = fixture(20);
        res.configure_tiering(2.0e-3, 1.0e-4, true);
        assert!(res.prefetch_enabled());
        assert!(res.try_prefetch(a(0), &mut kv, 1.0));
        let s = res.stats();
        assert_eq!((s.prefetches, s.loads, s.load_stall_steps), (1, 1, 0));
        // Already in flight: a second prefetch is a no-op.
        assert!(!res.try_prefetch(a(0), &mut kv, 1.0));
        assert_eq!(res.stats().prefetches, 1);
        // Once matured, admission is a warm hit — the transfer rode the
        // queue wait instead of the critical path.
        let ready = res.earliest_pending_ready().unwrap();
        assert_eq!(res.admission_gate(a(0), &mut kv, ready), AdmitGate::Hit);
        // Zero-cost config: prefetch is a documented no-op.
        let (mut res2, mut kv2) = fixture(20);
        res2.configure_tiering(0.0, 0.0, true);
        assert!(!res2.try_prefetch(a(0), &mut kv2, 1.0));
        assert_eq!(res2.stats().prefetches, 0);
    }
}
