//! Adapter-weight residency: ref-counted loads with LRU eviction, paged
//! against the unified KV memory budget.
//!
//! Before this module the engine pretended every registered adapter's
//! weights were permanently GPU-resident — free capacity the KV cache
//! never saw. S-LoRA (arXiv 2311.03285) serves thousands of adapters by
//! paging weights in the same unified pool as KV cache; this manager is
//! that policy layer over [`crate::memory::MemoryBudget`]:
//!
//! - **Load** claims `weight_blocks` pages from the shared
//!   [`crate::kvcache::KvCacheManager`] pool (evicting cold cached KV
//!   content if needed, never referenced blocks).
//! - **Refs** count running requests using the adapter. Admission acquires,
//!   preemption and completion release; at zero refs the adapter stays
//!   resident (warm) but becomes evictable.
//! - **Eviction** is LRU over idle (ref == 0) residents, triggered when a
//!   load or a KV allocation needs room — the two sides reclaim from each
//!   other under one policy (FASTLIBRA-style co-management).
//!
//! Loads are modeled as instantaneous (accounting, not transfer time);
//! what the engine observes is the *admission stall* when memory is not
//! reclaimable yet, surfaced via [`ResidencyStats::load_stall_steps`].

use crate::config::ModelConfig;
use crate::kvcache::block::BlockId;
use crate::kvcache::manager::KvCacheManager;
use crate::util::fxmap::FxHashMap;

use super::{AdapterId, AdapterRegistry};

/// Counters exported through the metrics registry
/// (`alora_serve_adapter_*`) and `GET /cluster`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Weight loads performed (adapter became resident).
    pub loads: u64,
    /// Idle adapters evicted to reclaim memory.
    pub evictions: u64,
    /// Scheduler steps where admission stalled on a failed weight load.
    pub load_stall_steps: u64,
    /// Adapter-targeted admissions.
    pub adapter_admissions: u64,
    /// ...whose adapter was already resident (no load on the critical path).
    pub adapter_admission_hits: u64,
}

impl ResidencyStats {
    /// Fraction of adapter admissions that found their weights resident —
    /// the residency analogue of the prefix-cache hit rate.
    pub fn hit_rate(&self) -> f64 {
        if self.adapter_admissions == 0 {
            0.0
        } else {
            self.adapter_admission_hits as f64 / self.adapter_admissions as f64
        }
    }
}

#[derive(Debug)]
struct Resident {
    /// Pages claimed from the shared pool (hashless, budget-charged).
    blocks: Vec<BlockId>,
    /// Running requests currently using this adapter.
    refs: u32,
    /// Monotonic LRU stamp (load / acquire / release all touch it).
    last_used: u64,
}

/// Ref-counted adapter-weight residency with LRU eviction of idle
/// adapters, charging against the same block budget as KV allocation.
#[derive(Debug)]
pub struct AdapterResidency {
    enabled: bool,
    /// Per-adapter weight cost in KV-block-equivalents (registry order).
    weight_blocks: Vec<usize>,
    resident: FxHashMap<u32, Resident>,
    tick: u64,
    stats: ResidencyStats,
}

impl AdapterResidency {
    /// Derive per-adapter weight costs from the registry and model dims.
    /// With `enabled = false` this is the pre-paging always-resident model:
    /// every query reports resident, nothing is charged, no stats move.
    pub fn new(
        registry: &AdapterRegistry,
        model: &ModelConfig,
        block_size: u32,
        enabled: bool,
    ) -> Self {
        AdapterResidency {
            enabled,
            weight_blocks: registry
                .iter()
                .map(|a| a.weight_blocks(model, block_size))
                .collect(),
            resident: FxHashMap::default(),
            tick: 0,
            stats: ResidencyStats::default(),
        }
    }

    /// Always-resident stub for tests and adapter-free fixtures.
    pub fn disabled() -> Self {
        AdapterResidency {
            enabled: false,
            weight_blocks: Vec::new(),
            resident: FxHashMap::default(),
            tick: 0,
            stats: ResidencyStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn stats(&self) -> ResidencyStats {
        self.stats
    }

    /// Weight cost of one adapter in blocks; 0 when paging is disabled
    /// (weights are free under always-resident semantics).
    pub fn weight_blocks_of(&self, aid: AdapterId) -> usize {
        if !self.enabled {
            return 0;
        }
        self.weight_blocks.get(aid.0 as usize).copied().unwrap_or(1)
    }

    pub fn is_resident(&self, aid: AdapterId) -> bool {
        !self.enabled || self.resident.contains_key(&aid.0)
    }

    /// Blocks an admission of `adapter` would add for weights on top of its
    /// KV demand — the admission watermark's adapter-load term.
    pub fn pending_load_blocks(&self, adapter: Option<AdapterId>) -> usize {
        match adapter {
            Some(aid) if self.enabled && !self.resident.contains_key(&aid.0) => {
                self.weight_blocks_of(aid)
            }
            _ => 0,
        }
    }

    /// Resident adapter ids, ascending (stable for stats/JSON).
    pub fn resident_ids(&self) -> Vec<u32> {
        let mut ids: Vec<u32> = self.resident.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    pub fn num_resident(&self) -> usize {
        self.resident.len()
    }

    /// Total pages currently charged to adapter weights.
    pub fn resident_blocks(&self) -> usize {
        self.resident.values().map(|e| e.blocks.len()).sum()
    }

    fn touch(&mut self) -> u64 {
        let t = self.tick;
        self.tick += 1;
        t
    }

    /// Make `aid` resident, loading its weights if needed. A load claims
    /// pages from the shared pool; under pressure it evicts idle adapters
    /// (LRU first, never `aid` itself, never one with running users) until
    /// the claim fits. False = memory not reclaimable right now — the
    /// caller defers admission and counts a stall.
    pub fn ensure_resident(&mut self, aid: AdapterId, kv: &mut KvCacheManager) -> bool {
        if !self.enabled || self.resident.contains_key(&aid.0) {
            return true;
        }
        let need = self.weight_blocks_of(aid);
        loop {
            if let Some(blocks) = kv.claim_adapter_blocks(need) {
                let t = self.touch();
                self.resident.insert(aid.0, Resident { blocks, refs: 0, last_used: t });
                self.stats.loads += 1;
                return true;
            }
            if !self.evict_one_idle_except(kv, Some(aid)) {
                return false;
            }
        }
    }

    /// Count an adapter admission: bump the adapter's ref (it must be
    /// resident — the scheduler calls [`Self::ensure_resident`] first) and
    /// record whether the weights were already warm when admission began.
    pub fn acquire(&mut self, aid: AdapterId, was_resident: bool) {
        if !self.enabled {
            return;
        }
        self.stats.adapter_admissions += 1;
        if was_resident {
            self.stats.adapter_admission_hits += 1;
        }
        let t = self.touch();
        let e = self
            .resident
            .get_mut(&aid.0)
            .expect("acquire of a non-resident adapter");
        e.refs += 1;
        e.last_used = t;
    }

    /// A running request using `aid` left the running set (finished or
    /// preempted). At zero refs the adapter stays warm but evictable.
    pub fn release(&mut self, aid: AdapterId) {
        if !self.enabled {
            return;
        }
        let t = self.touch();
        let e = self
            .resident
            .get_mut(&aid.0)
            .expect("release of a non-resident adapter");
        assert!(e.refs > 0, "release without acquire for adapter {}", aid.0);
        e.refs -= 1;
        e.last_used = t;
    }

    /// Evict the least-recently-used idle adapter (ref == 0), returning its
    /// pages to the shared pool. False when no adapter is evictable.
    pub fn evict_one_idle(&mut self, kv: &mut KvCacheManager) -> bool {
        self.evict_one_idle_except(kv, None)
    }

    /// [`Self::evict_one_idle`] excluding one id — a load in progress must
    /// not evict the adapter it is loading.
    pub fn evict_one_idle_except(
        &mut self,
        kv: &mut KvCacheManager,
        except: Option<AdapterId>,
    ) -> bool {
        if !self.enabled {
            return false;
        }
        // Deterministic LRU: `last_used` stamps are unique (monotonic
        // tick), so the min is unambiguous regardless of map order.
        let victim = self
            .resident
            .iter()
            .filter(|(id, e)| e.refs == 0 && Some(AdapterId(**id)) != except)
            .min_by_key(|(id, e)| (e.last_used, **id))
            .map(|(id, _)| *id);
        match victim {
            Some(id) => {
                let e = self.resident.remove(&id).expect("victim vanished");
                kv.release_adapter_blocks(&e.blocks);
                self.stats.evictions += 1;
                true
            }
            None => false,
        }
    }

    /// Evict every idle resident (replica failover: the device's weight
    /// pages are gone; the caller has already released all refs). Returns
    /// adapters evicted.
    pub fn evict_all_idle(&mut self, kv: &mut KvCacheManager) -> usize {
        let mut n = 0;
        while self.evict_one_idle(kv) {
            n += 1;
        }
        n
    }

    /// Count one scheduler step that stalled admission on a failed load.
    pub fn note_stall(&mut self) {
        if self.enabled {
            self.stats.load_stall_steps += 1;
        }
    }

    /// Test hook: per-entry consistency (page counts match the cost model).
    #[doc(hidden)]
    pub fn check_invariants(&self) -> Result<(), String> {
        for (id, e) in &self.resident {
            let want = self.weight_blocks.get(*id as usize).copied().unwrap_or(1);
            if e.blocks.len() != want {
                return Err(format!(
                    "adapter {id}: holds {} pages, cost model says {want}",
                    e.blocks.len()
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    /// 3 rank-32 aLoRAs on the tiny model: 8 pages each (see
    /// `adapter::tests::weight_cost_model_scales_with_rank_and_quantizes_up`).
    fn fixture(pool_blocks: u32) -> (AdapterResidency, KvCacheManager) {
        let reg = AdapterRegistry::tiny_default(3, 512, 4);
        let model = presets::tiny().model;
        let res = AdapterResidency::new(&reg, &model, 16, true);
        let kv = KvCacheManager::new(pool_blocks, 16, true);
        (res, kv)
    }

    fn a(i: u32) -> AdapterId {
        AdapterId(i)
    }

    #[test]
    fn load_charges_budget_and_lru_evicts_idle() {
        let (mut res, mut kv) = fixture(20);
        assert!(res.ensure_resident(a(0), &mut kv));
        assert!(res.ensure_resident(a(1), &mut kv));
        assert_eq!(res.resident_blocks(), 16);
        assert_eq!(kv.budget().adapter_blocks(), 16);
        assert_eq!(kv.num_free_blocks(), 4);
        // Third adapter needs 8 pages, only 4 free: the LRU idle adapter
        // (0, loaded first, untouched since) is evicted to make room.
        assert!(res.ensure_resident(a(2), &mut kv));
        assert_eq!(res.resident_ids(), vec![1, 2]);
        assert_eq!(res.stats().loads, 3);
        assert_eq!(res.stats().evictions, 1);
        assert_eq!(kv.budget().adapter_blocks(), 16);
        res.check_invariants().unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn refs_pin_adapters_and_release_unpins() {
        let (mut res, mut kv) = fixture(20);
        assert!(res.ensure_resident(a(0), &mut kv));
        res.acquire(a(0), true);
        assert!(res.ensure_resident(a(1), &mut kv));
        // Adapter 0 is in use: loading 2 must evict 1 (idle), never 0.
        assert!(res.ensure_resident(a(2), &mut kv));
        assert_eq!(res.resident_ids(), vec![0, 2]);
        // Release makes 0 evictable but also touches its LRU stamp, so the
        // next eviction takes 2 (stamped at load, before 0's release).
        res.release(a(0));
        assert!(res.ensure_resident(a(1), &mut kv));
        assert_eq!(res.resident_ids(), vec![0, 1]);
        assert_eq!(res.stats().evictions, 2);
        res.check_invariants().unwrap();
    }

    #[test]
    fn kv_pressure_reclaims_idle_adapters() {
        let (mut res, mut kv) = fixture(16);
        assert!(res.ensure_resident(a(0), &mut kv));
        assert!(res.ensure_resident(a(1), &mut kv));
        assert_eq!(kv.num_free_blocks(), 0);
        // A KV caller under pressure evicts one idle adapter and retries —
        // the other direction of the shared budget.
        assert!(res.evict_one_idle(&mut kv));
        assert_eq!(kv.num_free_blocks(), 8);
        kv.start_request(1, &[], 64);
        assert!(kv.ensure_capacity(1, 64));
        kv.free_request(1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn load_fails_only_when_nothing_is_reclaimable() {
        let (mut res, mut kv) = fixture(16);
        assert!(res.ensure_resident(a(0), &mut kv));
        res.acquire(a(0), true);
        assert!(res.ensure_resident(a(1), &mut kv));
        res.acquire(a(1), false);
        // Both residents pinned, zero free: adapter 2 cannot load.
        assert!(!res.ensure_resident(a(2), &mut kv));
        res.note_stall();
        assert_eq!(res.stats().load_stall_steps, 1);
        // A release unpins 1 → the load now succeeds by evicting it.
        res.release(a(1));
        assert!(res.ensure_resident(a(2), &mut kv));
        assert_eq!(res.resident_ids(), vec![0, 2]);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_hit_accounting() {
        let (mut res, mut kv) = fixture(20);
        let was = res.is_resident(a(0));
        assert!(!was);
        assert!(res.ensure_resident(a(0), &mut kv));
        res.acquire(a(0), was);
        res.release(a(0));
        let was = res.is_resident(a(0));
        assert!(was, "idle resident stays warm");
        assert!(res.ensure_resident(a(0), &mut kv));
        res.acquire(a(0), was);
        let s = res.stats();
        assert_eq!(s.adapter_admissions, 2);
        assert_eq!(s.adapter_admission_hits, 1);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(s.loads, 1, "second admission reused the resident weights");
    }

    #[test]
    fn disabled_is_always_resident_and_free() {
        let mut res = AdapterResidency::disabled();
        let mut kv = KvCacheManager::new(4, 16, true);
        assert!(res.is_resident(a(7)));
        assert_eq!(res.weight_blocks_of(a(7)), 0);
        assert_eq!(res.pending_load_blocks(Some(a(7))), 0);
        assert!(res.ensure_resident(a(7), &mut kv));
        res.acquire(a(7), true);
        res.release(a(7));
        assert!(!res.evict_one_idle(&mut kv));
        res.note_stall();
        assert_eq!(res.stats(), ResidencyStats::default());
        assert_eq!(kv.num_free_blocks(), 4, "nothing charged");
    }
}
