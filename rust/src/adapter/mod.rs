//! Adapter registry: standard LoRA vs Activated LoRA (aLoRA).
//!
//! An aLoRA adapter is identified by its *invocation tokens* field (paper
//! Figure 5): when a request targets an aLoRA, the engine scans the prompt
//! for the adapter's invocation sequence to locate the activation point;
//! everything before it keeps base-model attention weights and is therefore
//! cache-interchangeable with the base model.

pub mod residency;

use crate::config::ModelConfig;
use crate::kvcache::prefix::HashContext;

pub use residency::{AdapterResidency, AdmitGate, ResidencyStats};

/// Internal adapter ID (index into the registry).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AdapterId(pub u32);

#[derive(Debug, Clone, PartialEq)]
pub enum AdapterKind {
    /// Standard LoRA: adapts every token; cache isolated per adapter.
    Lora,
    /// Activated LoRA: adapts only tokens from the invocation sequence on.
    ALora {
        /// The activation token sequence baked in at adapter training time.
        invocation_tokens: Vec<u32>,
    },
}

#[derive(Debug, Clone, PartialEq)]
pub struct Adapter {
    pub id: AdapterId,
    pub name: String,
    pub kind: AdapterKind,
    /// Low-rank dimension (paper: 8 for LoRA, 32 for aLoRA).
    pub rank: u32,
}

impl Adapter {
    pub fn is_alora(&self) -> bool {
        matches!(self.kind, AdapterKind::ALora { .. })
    }

    /// Device bytes this adapter's weights occupy when resident: per layer,
    /// the four adapted attention projections (q, k, v, o) each carry an
    /// A (d_model × rank) and a B (rank × d_model) matrix.
    pub fn weight_bytes(&self, model: &ModelConfig) -> u64 {
        model.n_layers as u64
            * 4 // q, k, v, o projections
            * 2 // A and B low-rank factors
            * model.d_model as u64
            * self.rank as u64
            * model.dtype_bytes as u64
    }

    /// Weight footprint quantized to KV-block-equivalents — the unit the
    /// unified [`crate::memory::MemoryBudget`] is denominated in. Always at
    /// least 1: a resident adapter occupies a page even if its weights are
    /// smaller than one KV block.
    pub fn weight_blocks(&self, model: &ModelConfig, block_size: u32) -> usize {
        let block_bytes = model.kv_bytes_per_token() * block_size as f64;
        ((self.weight_bytes(model) as f64 / block_bytes).ceil() as usize).max(1)
    }

    pub fn invocation_tokens(&self) -> Option<&[u32]> {
        match &self.kind {
            AdapterKind::ALora { invocation_tokens } => Some(invocation_tokens),
            AdapterKind::Lora => None,
        }
    }
}

/// Where an aLoRA activates within a prompt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    /// Token index where the invocation sequence starts. Tokens at indices
    /// `< start` are pre-activation (base-identical K/V).
    At { start: usize },
    /// Invocation sequence not present in the prompt: the adapter
    /// activates from the first generated token (vLLM appends the
    /// invocation; we model the equivalent "activate at end of prompt").
    EndOfPrompt,
}

impl Activation {
    pub fn start(&self, prompt_len: usize) -> usize {
        match *self {
            Activation::At { start } => start,
            Activation::EndOfPrompt => prompt_len,
        }
    }
}

#[derive(Debug, Default)]
pub struct AdapterRegistry {
    adapters: Vec<Adapter>,
}

impl AdapterRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Registry mirroring the AOT-baked adapters of the `tiny` model:
    /// aLoRAs 0..n with python/compile/configs.py invocation sequences.
    pub fn tiny_default(n_adapters: u32, vocab: u32, inv_len: u32) -> Self {
        let mut reg = Self::new();
        for a in 0..n_adapters {
            let base = vocab - (a + 1) * inv_len;
            reg.register(
                format!("alora-{a}"),
                AdapterKind::ALora {
                    invocation_tokens: (base..base + inv_len).collect(),
                },
                32,
            );
        }
        reg
    }

    pub fn register(&mut self, name: impl Into<String>, kind: AdapterKind, rank: u32) -> AdapterId {
        let id = AdapterId(self.adapters.len() as u32);
        self.adapters.push(Adapter { id, name: name.into(), kind, rank });
        id
    }

    pub fn get(&self, id: AdapterId) -> Option<&Adapter> {
        self.adapters.get(id.0 as usize)
    }

    pub fn by_name(&self, name: &str) -> Option<&Adapter> {
        self.adapters.iter().find(|a| a.name == name)
    }

    pub fn len(&self) -> usize {
        self.adapters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.adapters.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Adapter> {
        self.adapters.iter()
    }

    /// Locate the aLoRA activation point in `prompt` (paper Figure 5: the
    /// *last* occurrence of the invocation sequence governs — re-invocation
    /// deeper in a conversation re-activates from there).
    pub fn find_activation(&self, id: AdapterId, prompt: &[u32]) -> Option<Activation> {
        let adapter = self.get(id)?;
        let inv = adapter.invocation_tokens()?;
        if inv.is_empty() || prompt.len() < inv.len() {
            return Some(Activation::EndOfPrompt);
        }
        // rfind of the subsequence
        for start in (0..=prompt.len() - inv.len()).rev() {
            if &prompt[start..start + inv.len()] == inv {
                return Some(Activation::At { start });
            }
        }
        Some(Activation::EndOfPrompt)
    }

    /// Derive a request's activation start and salting context exactly as
    /// submission does (activation scan + salting policy): base requests
    /// carry only the tenant salt, adapter requests locate their
    /// activation point first. Returns None for an unknown adapter. The
    /// single source of truth shared by `Engine::submit_salted` and the
    /// cluster router — the router's affinity chain must be byte-identical
    /// to the chain admission will present.
    pub fn request_hash_context(
        &self,
        adapter: Option<AdapterId>,
        prompt: &[u32],
        base_aligned: bool,
        cache_salt: u64,
    ) -> Option<(usize, HashContext)> {
        match adapter {
            None => Some((prompt.len(), HashContext { cache_salt, ..HashContext::base() })),
            Some(aid) => {
                let a = self.get(aid)?;
                // aLoRA identification (paper Figure 5): locate the
                // activation point; LoRA adapts everything (activation at
                // 0); base adapts nothing (activation at prompt end).
                let start = match self.find_activation(aid, prompt) {
                    Some(act) => act.start(prompt.len()),
                    None => {
                        debug_assert!(!a.is_alora());
                        0 // standard LoRA: adapted from the first token
                    }
                };
                Some((start, self.hash_context(Some(aid), start, base_aligned, cache_salt)))
            }
        }
    }

    /// Build the hash-chain salting context for a request (None adapter =
    /// base model). `base_aligned` is the engine feature flag.
    pub fn hash_context(
        &self,
        adapter: Option<AdapterId>,
        activation_start: usize,
        base_aligned: bool,
        cache_salt: u64,
    ) -> HashContext {
        match adapter {
            None => HashContext { cache_salt, ..HashContext::base() },
            Some(id) => {
                let a = self.get(id).expect("unknown adapter");
                HashContext {
                    adapter_id: Some(id.0),
                    is_alora: a.is_alora(),
                    inv_start: activation_start,
                    base_aligned,
                    cache_salt,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> AdapterRegistry {
        let mut r = AdapterRegistry::new();
        r.register("lora-a", AdapterKind::Lora, 8);
        r.register(
            "alora-b",
            AdapterKind::ALora { invocation_tokens: vec![100, 101, 102] },
            32,
        );
        r
    }

    #[test]
    fn register_and_lookup() {
        let r = reg();
        assert_eq!(r.len(), 2);
        assert_eq!(r.by_name("alora-b").unwrap().id, AdapterId(1));
        assert!(!r.get(AdapterId(0)).unwrap().is_alora());
        assert!(r.get(AdapterId(1)).unwrap().is_alora());
        assert!(r.get(AdapterId(9)).is_none());
    }

    #[test]
    fn finds_activation_sequence() {
        let r = reg();
        let prompt = [1, 2, 100, 101, 102, 7, 8];
        match r.find_activation(AdapterId(1), &prompt) {
            Some(Activation::At { start }) => assert_eq!(start, 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn last_occurrence_wins() {
        let r = reg();
        let prompt = [100, 101, 102, 5, 100, 101, 102, 9];
        match r.find_activation(AdapterId(1), &prompt) {
            Some(Activation::At { start }) => assert_eq!(start, 4),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn missing_sequence_activates_at_end() {
        let r = reg();
        let prompt = [1, 2, 3];
        assert_eq!(
            r.find_activation(AdapterId(1), &prompt),
            Some(Activation::EndOfPrompt)
        );
        assert_eq!(Activation::EndOfPrompt.start(3), 3);
    }

    #[test]
    fn lora_has_no_activation() {
        let r = reg();
        assert_eq!(r.find_activation(AdapterId(0), &[1, 2, 3]), None);
    }

    #[test]
    fn tiny_default_matches_python_invocations() {
        // python/compile/configs.py: base = vocab - (a+1)*inv_len
        let r = AdapterRegistry::tiny_default(3, 512, 4);
        assert_eq!(
            r.get(AdapterId(0)).unwrap().invocation_tokens().unwrap(),
            &[508, 509, 510, 511]
        );
        assert_eq!(
            r.get(AdapterId(2)).unwrap().invocation_tokens().unwrap(),
            &[500, 501, 502, 503]
        );
    }

    #[test]
    fn request_hash_context_mirrors_submission() {
        let r = reg();
        // Base: activation at prompt end, salt carried through.
        let (start, ctx) = r.request_hash_context(None, &[1, 2, 3], true, 9).unwrap();
        assert_eq!(start, 3);
        assert_eq!(ctx.adapter_id, None);
        assert_eq!(ctx.cache_salt, 9);
        // aLoRA: activation located in the prompt.
        let prompt = [1, 2, 100, 101, 102, 7];
        let (start, ctx) = r
            .request_hash_context(Some(AdapterId(1)), &prompt, true, 0)
            .unwrap();
        assert_eq!(start, 2);
        assert!(ctx.is_alora);
        assert_eq!(ctx.inv_start, 2);
        // LoRA: adapted from the first token.
        let (start, ctx) = r
            .request_hash_context(Some(AdapterId(0)), &prompt, true, 0)
            .unwrap();
        assert_eq!(start, 0);
        assert!(!ctx.is_alora);
        // Unknown adapter: None, not a panic.
        assert!(r.request_hash_context(Some(AdapterId(7)), &prompt, true, 0).is_none());
    }

    #[test]
    fn weight_cost_model_scales_with_rank_and_quantizes_up() {
        let r = reg();
        let model = crate::config::presets::granite_8b().model;
        let lora = r.get(AdapterId(0)).unwrap(); // rank 8
        let alora = r.get(AdapterId(1)).unwrap(); // rank 32
        // 40 layers × 4 proj × 2 factors × 4096 × rank × 2 bytes.
        assert_eq!(lora.weight_bytes(&model), 20_971_520);
        assert_eq!(alora.weight_bytes(&model), 83_886_080);
        // KV block = 16 tokens × 163840 B/token = 2,621,440 B.
        assert_eq!(lora.weight_blocks(&model, 16), 8);
        assert_eq!(alora.weight_blocks(&model, 16), 32);
        // Tiny model: weights smaller than pool geometry still round up
        // and never quantize to zero blocks.
        let tiny = crate::config::presets::tiny().model;
        assert_eq!(alora.weight_blocks(&tiny, 16), 8); // 524288 B / 65536 B
        assert!(lora.weight_blocks(&tiny, 16) >= 1);
    }

    #[test]
    fn hash_context_for_each_kind() {
        let r = reg();
        let base = r.hash_context(None, 0, true, 0);
        assert_eq!(base.adapter_id, None);
        let lora = r.hash_context(Some(AdapterId(0)), 0, true, 0);
        assert_eq!(lora.adapter_id, Some(0));
        assert!(!lora.is_alora);
        let alora = r.hash_context(Some(AdapterId(1)), 42, true, 0);
        assert!(alora.is_alora);
        assert_eq!(alora.inv_start, 42);
    }
}
