//! HTTP entrypoint (vLLM-style): `/generate`, `/metrics`, `/health`.
//!
//! Hand-rolled HTTP/1.1 over std TCP (no tokio in the offline build — see
//! DESIGN.md §7). A dedicated driver thread owns engine stepping; handler
//! threads submit requests and block on a condvar until their request
//! completes. Request lifecycle timestamps still come from the engine's
//! virtual clock, so `/metrics` exposes the same Table-2 series the
//! figure harness reads.
//!
//! API:
//!   POST /generate  {"prompt": [1,2,3], "adapter": "alora-0"|null,
//!                    "max_new_tokens": 16}
//!     -> {"id": 0, "tokens": [...], "e2e_s": ..., "ttft_s": ...,
//!         "cache_hit_rate": ...}
//!   POST /pipeline  JSON stage-graph spec (coordinator::spec format:
//!                   {"stages": [{"name", "adapter", "gen", "prompt",
//!                   "invoke", "after", "priority"}, ...]})
//!     -> {"makespan_s": ..., "stages": [{"name", "tokens", "e2e_s",
//!         "ttft_s", "queue_s", "prefill_s", "decode_s",
//!         "cache_hit_rate", ...}, ...]}
//!   GET /metrics    Prometheus text exposition
//!   GET /health     {"status": "ok"}
//!
//! /pipeline runs a whole multi-stage conversation DAG server-side: the
//! handler submits root stages, and as the driver thread retires each
//! stage the coordinator chains its children immediately — follow-ups hit
//! the engine while their parents' prefix blocks are still cache-hot,
//! concurrently with any /generate traffic sharing the engine.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::{spec, Coordinator};
use crate::engine::{Engine, Executor};
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams};
use crate::util::json::Json;

struct Shared<E: Executor> {
    engine: Mutex<EngineState<E>>,
    cv: Condvar,
    stop: AtomicBool,
}

struct EngineState<E: Executor> {
    engine: Engine<E>,
    done: HashMap<RequestId, RequestOutput>,
    /// Requests abandoned by their handler (e.g. a timed-out /pipeline):
    /// the driver drops their outputs instead of parking them in `done`
    /// forever.
    orphaned: HashSet<RequestId>,
}

/// A running server; `shutdown()` or drop stops the driver thread.
pub struct Server<E: Executor + Send + 'static> {
    shared: Arc<Shared<E>>,
    addr: std::net::SocketAddr,
    listener_handle: Option<std::thread::JoinHandle<()>>,
    driver_handle: Option<std::thread::JoinHandle<()>>,
}

impl<E: Executor + Send + 'static> Server<E> {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and start
    /// the driver + listener threads.
    pub fn start(engine: Engine<E>, addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            engine: Mutex::new(EngineState {
                engine,
                done: HashMap::new(),
                orphaned: HashSet::new(),
            }),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });

        // Driver thread: steps the engine whenever there is work.
        let driver = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || loop {
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                let mut st = shared.engine.lock().unwrap();
                if st.engine.has_work() {
                    st.engine.step();
                    for out in st.engine.take_finished() {
                        if !st.orphaned.remove(&out.id) {
                            st.done.insert(out.id, out);
                        }
                    }
                    shared.cv.notify_all();
                    drop(st);
                } else {
                    // Idle: wait for submissions.
                    let _ = shared
                        .cv
                        .wait_timeout(st, Duration::from_millis(10))
                        .unwrap();
                }
            })
        };

        // Listener thread: accept + handle connections (one thread each).
        let listener_handle = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || loop {
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let shared = Arc::clone(&shared);
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, &shared);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            })
        };

        Ok(Server {
            shared,
            addr: local,
            listener_handle: Some(listener_handle),
            driver_handle: Some(driver),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        if let Some(h) = self.listener_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.driver_handle.take() {
            let _ = h.join();
        }
    }
}

impl<E: Executor + Send + 'static> Drop for Server<E> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn<E: Executor>(mut stream: TcpStream, shared: &Shared<E>) -> anyhow::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }

    let (status, content) = route(&method, &path, &body, shared);
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {len}\r\nConnection: close\r\n\r\n",
        ctype = if path == "/metrics" { "text/plain; version=0.0.4" } else { "application/json" },
        len = content.len(),
    );
    stream.write_all(response.as_bytes())?;
    stream.write_all(content.as_bytes())?;
    Ok(())
}

fn route<E: Executor>(
    method: &str,
    path: &str,
    body: &[u8],
    shared: &Shared<E>,
) -> (&'static str, String) {
    match (method, path) {
        ("GET", "/health") => ("200 OK", r#"{"status":"ok"}"#.into()),
        ("GET", "/metrics") => {
            let st = shared.engine.lock().unwrap();
            ("200 OK", st.engine.metrics.render_prometheus())
        }
        ("POST", "/generate") => match generate(body, shared) {
            Ok(j) => ("200 OK", j.to_string()),
            Err(e) => (
                "400 Bad Request",
                Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
            ),
        },
        ("POST", "/pipeline") => match run_pipeline(body, shared) {
            Ok(j) => ("200 OK", j.to_string()),
            Err(e) => (
                "400 Bad Request",
                Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
            ),
        },
        _ => ("404 Not Found", r#"{"error":"not found"}"#.into()),
    }
}

fn generate<E: Executor>(body: &[u8], shared: &Shared<E>) -> anyhow::Result<Json> {
    let req = Json::parse(std::str::from_utf8(body)?)?;
    let prompt = req
        .get("prompt")
        .and_then(Json::u32_vec)
        .ok_or_else(|| anyhow::anyhow!("`prompt` must be an array of token ids"))?;
    let max_new = req
        .get("max_new_tokens")
        .and_then(Json::as_u64)
        .unwrap_or(16) as u32;
    let adapter_name = req.get("adapter").and_then(Json::as_str).map(str::to_string);

    let id = {
        let mut st = shared.engine.lock().unwrap();
        let target = match &adapter_name {
            None => ModelTarget::Base,
            Some(name) => {
                let a = st
                    .engine
                    .registry
                    .by_name(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown adapter `{name}`"))?;
                ModelTarget::Adapter(a.id)
            }
        };
        let id = st.engine.submit(
            target,
            prompt,
            SamplingParams { max_new_tokens: max_new, ..Default::default() },
        )?;
        shared.cv.notify_all();
        id
    };

    // Block until the driver finishes our request. Absolute deadline: the
    // condvar is woken on every driver step, so a per-wait timeout would
    // reset forever under concurrent traffic.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let mut st = shared.engine.lock().unwrap();
    loop {
        if let Some(out) = st.done.remove(&id) {
            return Ok(Json::obj(vec![
                ("id", Json::num(out.id.0 as f64)),
                (
                    "tokens",
                    Json::Arr(out.output_tokens.iter().map(|&t| Json::num(t as f64)).collect()),
                ),
                ("e2e_s", Json::num(out.timeline.e2e())),
                ("ttft_s", Json::num(out.timeline.ttft())),
                ("itl_s", Json::num(out.itl())),
                ("cache_hit_rate", Json::num(out.cache_hit_rate())),
                ("preemptions", Json::num(out.preemptions as f64)),
            ]));
        }
        let now = std::time::Instant::now();
        if now >= deadline {
            // Abandoning the request: let the driver drop its output
            // instead of parking it in `done` forever.
            st.orphaned.insert(id);
            anyhow::bail!("request {id:?} timed out");
        }
        let (guard, _) = shared.cv.wait_timeout(st, deadline - now).unwrap();
        st = guard;
    }
}

/// Drive one stage-graph conversation to completion over the shared
/// engine. The driver thread does the stepping; this handler consumes its
/// conversation's completions from `done` and lets the coordinator chain
/// children the moment their parents retire.
fn run_pipeline<E: Executor>(body: &[u8], shared: &Shared<E>) -> anyhow::Result<Json> {
    let spec_json = Json::parse(std::str::from_utf8(body)?)?;
    let mut st = shared.engine.lock().unwrap();
    let graph = spec::graph_from_json(&spec_json, &st.engine.registry)?;
    let n_stages = graph.len();
    let mut co = Coordinator::new();
    co.add_conversation(graph)?;
    let t0 = st.engine.clock();
    // Every failure past this point must fall through to the cleanup arm
    // below (partially-submitted roots are already in flight), so no `?`.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let mut outcome = co.submit_ready(&mut st.engine, 0).map(|_| ());
    shared.cv.notify_all();

    while outcome.is_ok() && !co.is_done() {
        let ready: Vec<RequestId> =
            st.done.keys().copied().filter(|id| co.owns(*id)).collect();
        if ready.is_empty() {
            // Absolute deadline: the condvar is woken on every driver
            // step, so a per-wait timeout would reset forever under
            // concurrent traffic.
            let now = std::time::Instant::now();
            if now >= deadline {
                outcome = Err(anyhow::anyhow!(
                    "pipeline timed out with {} of {n_stages} stages unfinished",
                    co.in_flight()
                ));
                break;
            }
            let (guard, _) = shared.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            continue;
        }
        for id in ready {
            let out = st.done.remove(&id).expect("checked above");
            if let Err(e) = co.on_finished(&mut st.engine, out) {
                outcome = Err(e);
                break;
            }
        }
        // Children were just submitted — wake the driver.
        shared.cv.notify_all();
    }

    match outcome {
        Ok(()) => {
            let makespan = st.engine.clock() - t0;
            Ok(spec::result_to_json(&co.into_result(makespan)))
        }
        Err(e) => {
            // Abandoning the conversation: drop anything of ours already
            // in `done` and mark the still-running stages orphaned so the
            // driver discards their outputs instead of leaking them.
            for id in co.in_flight_ids() {
                if st.done.remove(&id).is_none() {
                    st.orphaned.insert(id);
                }
            }
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::pipeline::workload;
    use crate::simulator::SimExecutor;

    fn start_sim_server() -> Server<SimExecutor> {
        let cfg = presets::granite_8b();
        let reg = workload::build_registry(2, cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&cfg);
        let engine = Engine::with_registry(cfg, reg, exec);
        Server::start(engine, "127.0.0.1:0").unwrap()
    }

    fn http(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn health_and_metrics_endpoints() {
        let mut srv = start_sim_server();
        let r = http(srv.addr(), "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""));
        let r = http(srv.addr(), "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("alora_serve_requests_received_total"));
        srv.shutdown();
    }

    #[test]
    fn generate_roundtrip_base_and_adapter() {
        let mut srv = start_sim_server();
        let body = r#"{"prompt": [1,2,3,4,5,6,7,8], "max_new_tokens": 4}"#;
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let r = http(srv.addr(), &req);
        assert!(r.contains("200 OK"), "{r}");
        assert!(r.contains("\"tokens\""));

        let body = r#"{"prompt": [1,2,3,4], "adapter": "alora-1", "max_new_tokens": 2}"#;
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let r = http(srv.addr(), &req);
        assert!(r.contains("200 OK"), "{r}");
        srv.shutdown();
    }

    #[test]
    fn pipeline_endpoint_runs_stage_graph() {
        let mut srv = start_sim_server();
        let prompt: Vec<String> = (0..256).map(|t| (t % 4000).to_string()).collect();
        let body = format!(
            r#"{{"stages": [
                {{"name": "draft", "gen": 32, "prompt": [[{p}]]}},
                {{"name": "check", "adapter": "alora-0", "gen": 8, "invoke": true,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}],
                  "priority": true}},
                {{"name": "final", "gen": 8,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}},
                             {{"output_of": "check"}}]}}
            ]}}"#,
            p = prompt.join(",")
        );
        let req = format!(
            "POST /pipeline HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let r = http(srv.addr(), &req);
        assert!(r.contains("200 OK"), "{r}");
        let j = Json::parse(r.lines().last().unwrap()).unwrap();
        let stages = j.get("stages").and_then(Json::as_arr).unwrap();
        assert_eq!(stages.len(), 3);
        // downstream stages reuse upstream KV over HTTP too
        for s in stages {
            let name = s.get("name").and_then(Json::as_str).unwrap();
            let hit = s.get("cache_hit_rate").and_then(Json::as_f64).unwrap();
            if name != "draft" {
                assert!(hit > 0.5, "{name}: hit {hit}");
            }
        }
        assert!(j.get("makespan_s").and_then(Json::as_f64).unwrap() > 0.0);
        srv.shutdown();
    }

    #[test]
    fn pipeline_endpoint_rejects_bad_spec() {
        let mut srv = start_sim_server();
        for body in [
            r#"{"stages": []}"#,
            r#"{"stages": [{"name": "a", "prompt": [{"output_of": "ghost"}]}]}"#,
        ] {
            let req = format!(
                "POST /pipeline HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            let r = http(srv.addr(), &req);
            assert!(r.contains("400"), "{r}");
        }
        srv.shutdown();
    }

    #[test]
    fn bad_requests_rejected() {
        let mut srv = start_sim_server();
        let body = r#"{"prompt": "nope"}"#;
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let r = http(srv.addr(), &req);
        assert!(r.contains("400"), "{r}");
        let r = http(srv.addr(), "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("404"), "{r}");
        srv.shutdown();
    }
}
