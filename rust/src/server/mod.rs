//! HTTP entrypoint (vLLM-style): `/generate`, `/pipeline`, `/metrics`,
//! `/cluster`, `/health`.
//!
//! Hand-rolled HTTP/1.1 over std TCP (no tokio in the offline build — see
//! DESIGN.md §7). The server drives any [`EngineDriver`] — one engine or a
//! replica [`crate::cluster::Cluster`] (cluster mode: every submission is
//! routed, `GET /cluster` reports fleet stats). A dedicated driver thread
//! owns stepping; handler threads submit requests and block on a condvar
//! until their request completes. Request lifecycle timestamps still come
//! from the virtual clock, so `/metrics` exposes the same Table-2 series
//! the figure harness reads.
//!
//! API:
//!   POST /generate  {"prompt": [1,2,3], "adapter": "alora-0"|null,
//!                    "max_new_tokens": 16,
//!                    "cache_salt": 7 | "tenant-name" (optional)}
//!     -> {"id": 0, "tokens": [...], "e2e_s": ..., "ttft_s": ...,
//!         "cache_hit_rate": ...}
//!   POST /pipeline  JSON stage-graph spec (coordinator::spec format:
//!                   {"stages": [{"name", "adapter", "gen", "prompt",
//!                   "invoke", "after", "priority"}, ...]})
//!     -> {"makespan_s": ..., "stages": [{"name", "tokens", "e2e_s",
//!         "ttft_s", "queue_s", "prefill_s", "decode_s",
//!         "cache_hit_rate", ...}, ...]}
//!                   or a BATCH of graphs: {"pipelines": [spec, ...]}
//!     -> {"makespan_s": ..., "pipelines": [{"stages": [...]} |
//!         {"error": "..."}, ...]}  (per-graph results and errors)
//!   GET /metrics    Prometheus text exposition (cluster mode: aggregated
//!                   + per-replica labeled families + routing counters)
//!   GET /cluster    fleet stats JSON (404 on a single engine)
//!   GET /health     {"status": "ok"}
//!
//! /pipeline runs whole multi-stage conversation DAGs server-side: the
//! handler submits root stages, and as the driver thread retires each
//! stage the coordinator chains its children immediately — follow-ups hit
//! the engine while their parents' prefix blocks are still cache-hot,
//! concurrently with any /generate traffic sharing the engine. A batch
//! request runs all its graphs through ONE coordinator over the shared
//! driver, so conversations interleave exactly as live traffic would.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::{spec, Coordinator};
use crate::engine::EngineDriver;
use crate::kvcache::hash::tenant_salt;
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams};
use crate::util::json::Json;

struct Shared<D: EngineDriver> {
    engine: Mutex<EngineState<D>>,
    cv: Condvar,
    stop: AtomicBool,
}

struct EngineState<D: EngineDriver> {
    engine: D,
    done: HashMap<RequestId, RequestOutput>,
    /// Requests abandoned by their handler (e.g. a timed-out /pipeline):
    /// the driver drops their outputs instead of parking them in `done`
    /// forever.
    orphaned: HashSet<RequestId>,
}

/// A running server; `shutdown()` or drop stops the driver thread.
pub struct Server<D: EngineDriver + Send + 'static> {
    shared: Arc<Shared<D>>,
    addr: std::net::SocketAddr,
    listener_handle: Option<std::thread::JoinHandle<()>>,
    driver_handle: Option<std::thread::JoinHandle<()>>,
}

impl<D: EngineDriver + Send + 'static> Server<D> {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and start
    /// the driver + listener threads. `engine` is any [`EngineDriver`]:
    /// pass an [`crate::engine::Engine`] for single-replica serving or a
    /// [`crate::cluster::Cluster`] for routed fleet serving.
    pub fn start(engine: D, addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            engine: Mutex::new(EngineState {
                engine,
                done: HashMap::new(),
                orphaned: HashSet::new(),
            }),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });

        // Driver thread: steps the engine whenever there is work.
        let driver = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || loop {
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                let mut st = shared.engine.lock().unwrap();
                if st.engine.has_work() {
                    st.engine.step();
                    for out in st.engine.take_finished() {
                        if !st.orphaned.remove(&out.id) {
                            st.done.insert(out.id, out);
                        }
                    }
                    shared.cv.notify_all();
                    drop(st);
                } else {
                    // Idle: wait for submissions.
                    let _ = shared
                        .cv
                        .wait_timeout(st, Duration::from_millis(10))
                        .unwrap();
                }
            })
        };

        // Listener thread: accept + handle connections (one thread each).
        let listener_handle = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || loop {
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let shared = Arc::clone(&shared);
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, &shared);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            })
        };

        Ok(Server {
            shared,
            addr: local,
            listener_handle: Some(listener_handle),
            driver_handle: Some(driver),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        if let Some(h) = self.listener_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.driver_handle.take() {
            let _ = h.join();
        }
    }
}

impl<D: EngineDriver + Send + 'static> Drop for Server<D> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn<D: EngineDriver>(mut stream: TcpStream, shared: &Shared<D>) -> anyhow::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }

    let (status, content) = route(&method, &path, &body, shared);
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {len}\r\nConnection: close\r\n\r\n",
        ctype = if path == "/metrics" { "text/plain; version=0.0.4" } else { "application/json" },
        len = content.len(),
    );
    stream.write_all(response.as_bytes())?;
    stream.write_all(content.as_bytes())?;
    Ok(())
}

fn route<D: EngineDriver>(
    method: &str,
    path: &str,
    body: &[u8],
    shared: &Shared<D>,
) -> (&'static str, String) {
    match (method, path) {
        ("GET", "/health") => ("200 OK", r#"{"status":"ok"}"#.into()),
        ("GET", "/metrics") => {
            let st = shared.engine.lock().unwrap();
            ("200 OK", st.engine.render_prometheus())
        }
        ("GET", "/cluster") => {
            let st = shared.engine.lock().unwrap();
            match st.engine.cluster_stats() {
                Some(cs) => ("200 OK", cs.to_json().to_string()),
                None => (
                    "404 Not Found",
                    r#"{"error":"not a cluster (started with a single engine)"}"#.into(),
                ),
            }
        }
        ("POST", "/generate") => match generate(body, shared) {
            Ok(j) => ("200 OK", j.to_string()),
            Err(e) => (
                "400 Bad Request",
                Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
            ),
        },
        ("POST", "/pipeline") => match run_pipeline(body, shared) {
            Ok(j) => ("200 OK", j.to_string()),
            Err(e) => (
                "400 Bad Request",
                Json::obj(vec![("error", Json::str(e.to_string()))]).to_string(),
            ),
        },
        _ => ("404 Not Found", r#"{"error":"not found"}"#.into()),
    }
}

/// Parse the optional multi-tenant `cache_salt` field: a raw u64, or a
/// tenant-name string hashed to a stable nonzero salt.
fn parse_cache_salt(req: &Json) -> anyhow::Result<u64> {
    match req.get("cache_salt") {
        None | Some(Json::Null) => Ok(0),
        Some(v) => {
            if let Some(n) = v.as_u64() {
                Ok(n)
            } else if let Some(s) = v.as_str() {
                Ok(tenant_salt(s))
            } else {
                anyhow::bail!("`cache_salt` must be an integer or a tenant string")
            }
        }
    }
}

/// Abandon one batch-`/pipeline` conversation after a submission failure:
/// hand its in-flight outputs to the orphan list (the driver discards
/// them) and record the per-entry error in input order. Shared by the
/// root-submission and chain-time failure paths so their bookkeeping
/// cannot diverge.
fn abandon_batch_entry<D: EngineDriver>(
    co: &mut Coordinator,
    st: &mut EngineState<D>,
    convs: &mut [Result<usize, String>],
    ci: usize,
    err: String,
) {
    for id in co.abandon_conversation(ci) {
        if st.done.remove(&id).is_none() {
            st.orphaned.insert(id);
        }
    }
    if let Some(idx) = convs.iter().position(|c| c.as_ref().ok() == Some(&ci)) {
        convs[idx] = Err(err);
    }
}

fn generate<D: EngineDriver>(body: &[u8], shared: &Shared<D>) -> anyhow::Result<Json> {
    let req = Json::parse(std::str::from_utf8(body)?)?;
    let prompt = req
        .get("prompt")
        .and_then(Json::u32_vec)
        .ok_or_else(|| anyhow::anyhow!("`prompt` must be an array of token ids"))?;
    let max_new = req
        .get("max_new_tokens")
        .and_then(Json::as_u64)
        .unwrap_or(16) as u32;
    let adapter_name = req.get("adapter").and_then(Json::as_str).map(str::to_string);
    let cache_salt = parse_cache_salt(&req)?;

    let id = {
        let mut st = shared.engine.lock().unwrap();
        let target = match &adapter_name {
            None => ModelTarget::Base,
            Some(name) => {
                let a = st
                    .engine
                    .registry()
                    .by_name(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown adapter `{name}`"))?;
                ModelTarget::Adapter(a.id)
            }
        };
        let id = st.engine.submit_salted(
            target,
            prompt,
            SamplingParams { max_new_tokens: max_new, ..Default::default() },
            false,
            cache_salt,
        )?;
        shared.cv.notify_all();
        id
    };

    // Block until the driver finishes our request. Absolute deadline: the
    // condvar is woken on every driver step, so a per-wait timeout would
    // reset forever under concurrent traffic.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let mut st = shared.engine.lock().unwrap();
    loop {
        if let Some(out) = st.done.remove(&id) {
            return Ok(Json::obj(vec![
                ("id", Json::num(out.id.0 as f64)),
                (
                    "tokens",
                    Json::Arr(out.output_tokens.iter().map(|&t| Json::num(t as f64)).collect()),
                ),
                ("e2e_s", Json::num(out.timeline.e2e())),
                ("ttft_s", Json::num(out.timeline.ttft())),
                ("itl_s", Json::num(out.itl())),
                ("cache_hit_rate", Json::num(out.cache_hit_rate())),
                ("preemptions", Json::num(out.preemptions as f64)),
            ]));
        }
        let now = std::time::Instant::now();
        if now >= deadline {
            // Abandoning the request: let the driver drop its output
            // instead of parking it in `done` forever.
            st.orphaned.insert(id);
            anyhow::bail!("request {id:?} timed out");
        }
        let (guard, _) = shared.cv.wait_timeout(st, deadline - now).unwrap();
        st = guard;
    }
}

/// Drive one or many stage-graph conversations to completion over the
/// shared engine. The driver thread does the stepping; this handler
/// consumes its conversations' completions from `done` and lets the
/// coordinator chain children the moment their parents retire.
///
/// Batch form (`{"pipelines": [spec, ...]}`): every parseable graph runs;
/// graphs that fail validation — or whose submission the engine rejects
/// at runtime (e.g. a stage exceeding max_seq_len) — get a per-entry
/// `error` in the response instead of failing the whole request (a 400
/// is reserved for structural problems — non-array `pipelines`, empty
/// batch, unparseable body).
fn run_pipeline<D: EngineDriver>(body: &[u8], shared: &Shared<D>) -> anyhow::Result<Json> {
    let spec_json = Json::parse(std::str::from_utf8(body)?)?;
    let mut st = shared.engine.lock().unwrap();
    let (specs, batched): (Vec<&Json>, bool) = match spec_json.get("pipelines") {
        Some(pj) => {
            let arr = pj
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("`pipelines` must be an array of specs"))?;
            anyhow::ensure!(!arr.is_empty(), "`pipelines` is empty");
            (arr.iter().collect(), true)
        }
        None => (vec![&spec_json], false),
    };
    let mut co = Coordinator::new();
    // Per input spec: the conversation index it became, or its error.
    let mut convs: Vec<Result<usize, String>> = Vec::new();
    for &sj in &specs {
        let parsed = spec::graph_from_json(sj, st.engine.registry())
            .and_then(|g| co.add_conversation(g));
        convs.push(parsed.map_err(|e| e.to_string()));
    }
    if !batched {
        // Single-spec form keeps its contract: invalid spec = 400.
        if let Err(e) = &convs[0] {
            anyhow::bail!("{e}");
        }
    }
    let n_stages: usize = convs
        .iter()
        .flatten()
        .map(|&ci| co.graph(ci).len())
        .sum();
    let t0 = st.engine.clock();
    // Every failure past this point must fall through to the cleanup arm
    // below (partially-submitted roots are already in flight), so no `?`.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let mut outcome = Ok(());
    for idx in 0..convs.len() {
        let Ok(&ci) = convs[idx].as_ref() else { continue };
        if let Err(e) = co.submit_ready(&mut st.engine, ci) {
            if batched {
                // Isolate the failing graph: abandon it (its partially
                // submitted roots keep running; their outputs get
                // discarded) and report it per-entry — a runtime reject
                // in one graph must not fail the rest of the batch.
                abandon_batch_entry(&mut co, &mut st, &mut convs, ci, e.to_string());
            } else {
                outcome = Err(e);
                break;
            }
        }
    }
    shared.cv.notify_all();

    while outcome.is_ok() && !co.is_done() {
        let ready: Vec<RequestId> =
            st.done.keys().copied().filter(|id| co.owns(*id)).collect();
        if ready.is_empty() {
            // Absolute deadline: the condvar is woken on every driver
            // step, so a per-wait timeout would reset forever under
            // concurrent traffic.
            let now = std::time::Instant::now();
            if now >= deadline {
                outcome = Err(anyhow::anyhow!(
                    "pipeline timed out with {} of {n_stages} stages unfinished",
                    co.in_flight()
                ));
                break;
            }
            let (guard, _) = shared.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            continue;
        }
        for id in ready {
            // An abandonment earlier in this drain may have already
            // discarded a sibling stage's output.
            let Some(out) = st.done.remove(&id) else { continue };
            let ci = co.conversation_of(id);
            if let Err(e) = co.on_finished(&mut st.engine, out) {
                // Child-stage submission can fail at chaining time (e.g. a
                // composed prompt outgrowing max_seq_len). In batch mode
                // that conversation alone is abandoned and reported
                // per-entry, same as a root-submission failure.
                match ci {
                    Some(ci) if batched => {
                        abandon_batch_entry(&mut co, &mut st, &mut convs, ci, e.to_string());
                    }
                    _ => {
                        outcome = Err(e);
                        break;
                    }
                }
            }
        }
        // Children were just submitted — wake the driver.
        shared.cv.notify_all();
    }

    match outcome {
        Ok(()) => {
            let makespan = st.engine.clock() - t0;
            let result = co.into_result(makespan);
            if batched {
                Ok(spec::batch_result_to_json(&result, &convs))
            } else {
                Ok(spec::result_to_json(&result))
            }
        }
        Err(e) => {
            // Abandoning the conversation: drop anything of ours already
            // in `done` and mark the still-running stages orphaned so the
            // driver discards their outputs instead of leaking them.
            for id in co.in_flight_ids() {
                if st.done.remove(&id).is_none() {
                    st.orphaned.insert(id);
                }
            }
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, RoutePolicy};
    use crate::config::presets;
    use crate::engine::Engine;
    use crate::pipeline::workload;
    use crate::simulator::SimExecutor;

    fn sim_engine() -> Engine<SimExecutor> {
        let cfg = presets::granite_8b();
        let reg = workload::build_registry(2, cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&cfg);
        Engine::with_registry(cfg, reg, exec)
    }

    fn start_sim_server() -> Server<Engine<SimExecutor>> {
        Server::start(sim_engine(), "127.0.0.1:0").unwrap()
    }

    fn start_cluster_server(n: usize) -> Server<Cluster<SimExecutor>> {
        let cluster =
            Cluster::from_factory(n, RoutePolicy::PrefixAffinity, |_| sim_engine()).unwrap();
        Server::start(cluster, "127.0.0.1:0").unwrap()
    }

    fn http(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn health_and_metrics_endpoints() {
        let mut srv = start_sim_server();
        let r = http(srv.addr(), "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""));
        let r = http(srv.addr(), "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("alora_serve_requests_received_total"));
        srv.shutdown();
    }

    #[test]
    fn generate_roundtrip_base_and_adapter() {
        let mut srv = start_sim_server();
        let body = r#"{"prompt": [1,2,3,4,5,6,7,8], "max_new_tokens": 4}"#;
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let r = http(srv.addr(), &req);
        assert!(r.contains("200 OK"), "{r}");
        assert!(r.contains("\"tokens\""));

        let body = r#"{"prompt": [1,2,3,4], "adapter": "alora-1", "max_new_tokens": 2}"#;
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let r = http(srv.addr(), &req);
        assert!(r.contains("200 OK"), "{r}");
        srv.shutdown();
    }

    #[test]
    fn pipeline_endpoint_runs_stage_graph() {
        let mut srv = start_sim_server();
        let prompt: Vec<String> = (0..256).map(|t| (t % 4000).to_string()).collect();
        let body = format!(
            r#"{{"stages": [
                {{"name": "draft", "gen": 32, "prompt": [[{p}]]}},
                {{"name": "check", "adapter": "alora-0", "gen": 8, "invoke": true,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}],
                  "priority": true}},
                {{"name": "final", "gen": 8,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}},
                             {{"output_of": "check"}}]}}
            ]}}"#,
            p = prompt.join(",")
        );
        let req = format!(
            "POST /pipeline HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let r = http(srv.addr(), &req);
        assert!(r.contains("200 OK"), "{r}");
        let j = Json::parse(r.lines().last().unwrap()).unwrap();
        let stages = j.get("stages").and_then(Json::as_arr).unwrap();
        assert_eq!(stages.len(), 3);
        // downstream stages reuse upstream KV over HTTP too
        for s in stages {
            let name = s.get("name").and_then(Json::as_str).unwrap();
            let hit = s.get("cache_hit_rate").and_then(Json::as_f64).unwrap();
            if name != "draft" {
                assert!(hit > 0.5, "{name}: hit {hit}");
            }
        }
        assert!(j.get("makespan_s").and_then(Json::as_f64).unwrap() > 0.0);
        srv.shutdown();
    }

    #[test]
    fn pipeline_endpoint_rejects_bad_spec() {
        let mut srv = start_sim_server();
        for body in [
            r#"{"stages": []}"#,
            r#"{"stages": [{"name": "a", "prompt": [{"output_of": "ghost"}]}]}"#,
        ] {
            let req = format!(
                "POST /pipeline HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            let r = http(srv.addr(), &req);
            assert!(r.contains("400"), "{r}");
        }
        srv.shutdown();
    }

    #[test]
    fn pipeline_endpoint_batches_graphs_with_per_graph_errors() {
        let mut srv = start_sim_server();
        let p: Vec<String> = (0..64).map(|t| (t % 4000).to_string()).collect();
        let good = format!(
            r#"{{"stages": [
                {{"name": "draft", "gen": 8, "prompt": [[{p}]]}},
                {{"name": "check", "adapter": "alora-0", "gen": 4, "invoke": true,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}]}}
            ]}}"#,
            p = p.join(",")
        );
        let bad = r#"{"stages": [{"name": "x", "prompt": [{"output_of": "ghost"}]}]}"#;
        let body = format!(r#"{{"pipelines": [{good}, {bad}, {good}]}}"#);
        let req = format!(
            "POST /pipeline HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let r = http(srv.addr(), &req);
        assert!(r.contains("200 OK"), "{r}");
        let j = Json::parse(r.lines().last().unwrap()).unwrap();
        let ps = j.get("pipelines").and_then(Json::as_arr).unwrap();
        assert_eq!(ps.len(), 3);
        for idx in [0usize, 2] {
            let stages = ps[idx].get("stages").and_then(Json::as_arr).unwrap();
            assert_eq!(stages.len(), 2, "pipeline {idx}");
            assert!(ps[idx].get("error").is_none());
        }
        assert!(ps[1].get("error").and_then(Json::as_str).unwrap().contains("ghost"));
        // A graph that passes validation but is rejected by the engine at
        // submission (gen beyond max_seq_len) is isolated the same way.
        let runtime_bad =
            r#"{"stages": [{"name": "x", "gen": 200000, "prompt": [[1,2,3]]}]}"#;
        let body = format!(r#"{{"pipelines": [{good}, {runtime_bad}]}}"#);
        let req = format!(
            "POST /pipeline HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let r = http(srv.addr(), &req);
        assert!(r.contains("200 OK"), "{r}");
        let j = Json::parse(r.lines().last().unwrap()).unwrap();
        let ps = j.get("pipelines").and_then(Json::as_arr).unwrap();
        assert_eq!(ps[0].get("stages").and_then(Json::as_arr).unwrap().len(), 2);
        assert!(ps[1]
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("max_seq_len"));
        // structural problems still 400
        for body in [r#"{"pipelines": []}"#, r#"{"pipelines": 5}"#] {
            let req = format!(
                "POST /pipeline HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            assert!(http(srv.addr(), &req).contains("400"));
        }
        srv.shutdown();
    }

    #[test]
    fn pipeline_batch_isolates_child_stage_submit_failure() {
        // tiny preset: max_seq_len 160 — a child whose composed prompt
        // outgrows it is rejected only at CHAINING time, after its root
        // already ran. The batch must still return the good graph's
        // results with a per-entry error for the bad one.
        let cfg = presets::tiny();
        let reg = crate::adapter::AdapterRegistry::tiny_default(2, 512, 4);
        let exec = SimExecutor::new(&cfg);
        let mut srv =
            Server::start(Engine::with_registry(cfg, reg, exec), "127.0.0.1:0").unwrap();
        let good = r#"{"stages": [{"name": "a", "gen": 8, "prompt": [[1,2,3,4,5,6,7,8]]}]}"#;
        let p64: Vec<String> = (0..64).map(|t| (t % 400).to_string()).collect();
        let bad = format!(
            r#"{{"stages": [
                {{"name": "draft", "gen": 32, "prompt": [[{p}]]}},
                {{"name": "kid", "gen": 80,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}]}}
            ]}}"#,
            p = p64.join(",")
        );
        let body = format!(r#"{{"pipelines": [{good}, {bad}]}}"#);
        let req = format!(
            "POST /pipeline HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let r = http(srv.addr(), &req);
        assert!(r.contains("200 OK"), "{r}");
        let j = Json::parse(r.lines().last().unwrap()).unwrap();
        let ps = j.get("pipelines").and_then(Json::as_arr).unwrap();
        assert_eq!(ps[0].get("stages").and_then(Json::as_arr).unwrap().len(), 1);
        assert!(ps[1]
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("max_seq_len"));
        srv.shutdown();
    }

    #[test]
    fn generate_cache_salt_isolates_tenants_over_http() {
        let mut srv = start_sim_server();
        let prompt: Vec<String> = (0..64).map(|t| t.to_string()).collect();
        let gen = |salt: &str| {
            let body = format!(
                r#"{{"prompt": [{}], "max_new_tokens": 2, "cache_salt": {salt}}}"#,
                prompt.join(",")
            );
            let req = format!(
                "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            let r = http(srv.addr(), &req);
            assert!(r.contains("200 OK"), "{r}");
            let j = Json::parse(r.lines().last().unwrap()).unwrap();
            j.get("cache_hit_rate").and_then(Json::as_f64).unwrap()
        };
        assert_eq!(gen("\"tenant-a\""), 0.0, "cold");
        assert!(gen("\"tenant-a\"") > 0.5, "same tenant rehits its prefix");
        assert_eq!(gen("\"tenant-b\""), 0.0, "tenants never share hits");
        assert_eq!(gen("7"), 0.0, "numeric salt is its own tenant");
        srv.shutdown();
    }

    #[test]
    fn cluster_mode_serves_and_reports_fleet_stats() {
        let mut srv = start_cluster_server(2);
        let prompt: Vec<String> = (0..64).map(|t| t.to_string()).collect();
        for _ in 0..2 {
            let body = format!(
                r#"{{"prompt": [{}], "max_new_tokens": 2}}"#,
                prompt.join(",")
            );
            let req = format!(
                "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            assert!(http(srv.addr(), &req).contains("200 OK"));
        }
        let r = http(srv.addr(), "GET /cluster HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"), "{r}");
        let j = Json::parse(r.lines().last().unwrap()).unwrap();
        assert_eq!(j.get("policy").and_then(Json::as_str), Some("prefix-affinity"));
        assert_eq!(j.get("replicas").and_then(Json::as_arr).unwrap().len(), 2);
        // Fleet dashboards get the per-replica config summary + adapter
        // residency without out-of-band config.
        let cfg = j.get("config").expect("config summary");
        assert_eq!(cfg.get("model").and_then(Json::as_str), Some("granite-8b"));
        assert!(cfg.get("total_blocks").and_then(Json::as_u64).unwrap() > 0);
        assert_eq!(cfg.get("adapter_paging").and_then(Json::as_bool), Some(false));
        let rep0 = &j.get("replicas").and_then(Json::as_arr).unwrap()[0];
        assert!(rep0.get("resident_adapters").and_then(Json::as_arr).is_some());
        assert!(rep0.get("adapter_loads").and_then(Json::as_u64).is_some());
        let m = http(srv.addr(), "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(m.contains("alora_serve_router_requests_routed_total"), "{m}");
        assert!(m.contains("alora_serve_replica_clock_seconds{replica=\"1\"}"));
        srv.shutdown();
        // Single-engine servers 404 the cluster endpoint.
        let mut single = start_sim_server();
        let r = http(single.addr(), "GET /cluster HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("404"), "{r}");
        single.shutdown();
    }

    #[test]
    fn bad_requests_rejected() {
        let mut srv = start_sim_server();
        let body = r#"{"prompt": "nope"}"#;
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let r = http(srv.addr(), &req);
        assert!(r.contains("400"), "{r}");
        let r = http(srv.addr(), "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("404"), "{r}");
        srv.shutdown();
    }
}
